"""ControlPlaneClient: typed SDK over the phys-MCP wire protocol.

The client gives remote callers the SAME types the in-process API returns —
``discover()`` yields real :class:`ResourceDescriptor` objects (rebuilt
through ``from_dict``, which is the descriptor-portability claim made
executable), ``invoke()`` returns the familiar ``(InvocationResult,
OrchestrationTrace)`` pair — so code written against an ``Orchestrator``
ports to a remote plane by swapping the object it calls.

Failures raise :class:`GatewayError` carrying the structured taxonomy code
plus the server's detail (full trace, twin ``invalidation_reason``), never
a bare HTTP error.

Wire codec (v1.2): construct with ``codec="binary"`` to negotiate the
compact binary envelope framing (``application/x-physmcp``) on both
directions — ``Content-Type`` names the request codec, ``Accept`` asks for
the response codec, and the default JSON client is byte-identical to v1.1
on the wire.

Transport: one keep-alive connection per calling thread, with
``TCP_NODELAY`` set (small control frames must not sit in Nagle buffers)
and a bounded LRU pool — connections owned by exited threads are reaped
and the pool never exceeds ``MAX_POOLED_CONNS`` sockets however many
threads churn through the client.

Coalescing (v1.2): :meth:`ControlPlaneClient.submit_coalesced` and
:meth:`ControlPlaneClient.invoke_coalesced` route through a transparent
micro-batching buffer — concurrent submitters share one
``/v1/submit_coalesced`` frame (group commit: whatever accumulates while
the previous flush is on the wire rides the next one), and their
completion waits share one ``/v1/poll_coalesced`` long-poll, so N
concurrent federated forwards cost ~2 round-trips instead of 2N.

Backpressure: ``QUEUE_SATURATED`` rejections carry the plane's live
``retry_after_s`` hint; :meth:`ControlPlaneClient.invoke` honors it with
jittered backoff (bounded by the task's own deadline budget) instead of
hammering a saturated plane.  Auth: construct with ``api_key=`` to send
``Authorization: Bearer`` on every request (keyed gateways refuse
credential-less planes with ``UNAUTHORIZED``).  Streaming:
:meth:`ControlPlaneClient.stream` opens one server-push subscription
(``/v1/stream``) that replaces a whole polling-cursor loop.
"""
# planelint: allow-file(clock-seam) — client-side SDK: runs in arbitrary
# processes against a real HTTP gateway; there is no injected plane clock
# on this side of the wire, so wall deadlines/backoff are intended.
from __future__ import annotations

import http.client
import random
import socket
import threading
import time
import urllib.parse
from collections import OrderedDict
from concurrent.futures import Future, TimeoutError as FutureTimeout
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.descriptors import ResourceDescriptor
from repro.core.errors import ControlPlaneError, ErrorCode, WireError
from repro.core.invocation import InvocationResult
from repro.core.orchestrator import OrchestrationTrace
from repro.core.tasks import TaskRequest
from repro.gateway import protocol as wire
from repro.gateway.stream import StreamFilter, TelemetryStream


class GatewayError(ControlPlaneError):
    """A wire request failed; ``code``/``message``/``detail`` mirror the
    server's structured error (``detail`` may carry the full trace and a
    twin's ``invalidation_reason``)."""

    @property
    def trace(self) -> Optional[OrchestrationTrace]:
        t = self.detail.get("trace")
        return wire.trace_from_wire(t) if t else None

    @property
    def invalidation_reason(self) -> Optional[str]:
        return self.detail.get("invalidation_reason")


class _NoDelayHTTPConnection(http.client.HTTPConnection):
    """Keep-alive connection with Nagle disabled, used for the chunked
    ``/v1/stream`` subscription (a long-lived connection where
    http.client's incremental chunked decoding earns its keep)."""

    #: set by the client around request/response so the pool reaper never
    #: closes a connection out from under a call in progress
    in_flight = False

    def connect(self):
        super().connect()
        try:
            self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass


class _WireConn:
    """Minimal keep-alive HTTP/1.1 connection for control frames.

    Replaces http.client on the request/response hot path: one ``sendall``
    per request (head + body pre-joined), one buffered read loop for the
    response, no intermediate response object.  A sub-millisecond wire
    budget leaves no room for http.client's per-call parsing machinery
    (~0.3 ms on loopback).  Nagle is disabled — control frames are small,
    and the server side already sets TCP_NODELAY on every accepted socket.
    """

    __slots__ = ("host", "port", "timeout", "sock", "_rbuf", "in_flight")

    def __init__(self, host: str, port: int, timeout: float):
        self.host = host
        self.port = port
        self.timeout = timeout
        self.sock: Optional[socket.socket] = None
        self._rbuf = b""
        self.in_flight = False

    def connect(self) -> None:
        self.sock = socket.create_connection((self.host, self.port),
                                             timeout=self.timeout)
        try:
            self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass
        self._rbuf = b""

    def close(self) -> None:
        sock, self.sock = self.sock, None
        self._rbuf = b""
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    def request(self, method: str, path: str, body: Optional[bytes],
                headers: Dict[str, str]) -> None:
        body = body or b""
        # work on a local ref: a concurrent close() nulls self.sock, and
        # that must surface as a retriable OSError, not an AttributeError
        sock = self.sock
        if sock is None:
            self.connect()
            sock = self.sock
            if sock is None:
                raise ConnectionError("connection closed while connecting")
        else:
            sock.settimeout(self.timeout)
        head = (f"{method} {path} HTTP/1.1\r\n"
                f"Host: {self.host}:{self.port}\r\n"
                f"Content-Length: {len(body)}\r\n"
                + "".join(f"{k}: {v}\r\n" for k, v in headers.items())
                ).encode("latin-1")
        sock.sendall(head + b"\r\n" + body)

    def getresponse(self) -> Tuple[int, Dict[str, str], bytes]:
        """Read one response: ``(status, lowercase headers, body)``.

        EOF before a complete response raises ``RemoteDisconnected`` so
        the caller's stale-keep-alive retry logic applies unchanged."""
        sock = self.sock
        if sock is None:
            raise http.client.RemoteDisconnected("connection closed")
        buf = self._rbuf
        while b"\r\n\r\n" not in buf:
            chunk = sock.recv(65536)
            if not chunk:
                self.close()
                raise http.client.RemoteDisconnected(
                    "server closed connection before a complete response")
            buf += chunk
        head, _, rest = buf.partition(b"\r\n\r\n")
        lines = head.split(b"\r\n")
        try:
            status = int(lines[0].split(None, 2)[1])
        except (IndexError, ValueError):
            self.close()
            raise http.client.BadStatusLine(
                lines[0].decode("latin-1", "replace")) from None
        hdrs: Dict[str, str] = {}
        for line in lines[1:]:
            key, _, value = line.partition(b":")
            hdrs[key.strip().lower().decode("latin-1")] = \
                value.strip().decode("latin-1")
        if "chunked" in hdrs.get("transfer-encoding", "").lower():
            # only /v1/stream chunks, and that rides _NoDelayHTTPConnection
            self.close()
            raise http.client.HTTPException(
                "unexpected chunked response on the control path")
        length = int(hdrs.get("content-length") or 0)
        while len(rest) < length:
            chunk = sock.recv(65536)
            if not chunk:
                self.close()
                raise http.client.RemoteDisconnected(
                    "connection lost mid-response")
            rest += chunk
        body, self._rbuf = rest[:length], rest[length:]
        if hdrs.get("connection", "").lower() == "close":
            self.close()
        return status, hdrs, body


class _Coalescer:
    """Transparent micro-batching submit buffer (group commit).

    Callers enqueue ``(task, deadline_s)`` and get a Future resolving to a
    ticket.  One flusher thread drains the buffer into
    ``/v1/submit_coalesced`` frames: the FIRST entry flushes immediately
    (an idle buffer adds no latency), and everything that arrives while a
    flush is on the wire rides the next frame — natural batching whose
    delay is bounded by one wire round-trip, plus an optional ``linger_s``
    for callers that prefer fuller frames.  A frame never exceeds
    ``MAX_BATCH`` entries, and entries carrying an explicit deadline skip
    the linger entirely (deadline pressure flushes).  Outcomes are
    per-entry: one stranger's malformed task fails only its own Future."""

    MAX_BATCH = 32

    def __init__(self, client: "ControlPlaneClient", linger_s: float = 0.0):
        self._client = client
        self.linger_s = max(0.0, linger_s)
        self._cond = threading.Condition()
        self._buf: List[Tuple[Dict, "Future[str]"]] = []
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        #: lifetime counters — the batching-ratio observability the
        #: benchmarks and federation tests read
        self.flushes = 0
        self.entries = 0

    def enqueue(self, task: TaskRequest,
                deadline_s: Optional[float] = None) -> "Future[str]":
        fut: "Future[str]" = Future()
        entry = {"task": wire.task_to_wire(task)}
        if deadline_s is not None:
            entry["deadline_s"] = deadline_s
        with self._cond:
            if self._closed:
                raise GatewayError(ErrorCode.PLANE_UNAVAILABLE,
                                   "client closed")
            self._buf.append((entry, fut))
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(
                    target=self._run, daemon=True,
                    name="phys-mcp-client-coalescer")
                self._thread.start()
            self._cond.notify()
        return fut

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._buf and not self._closed:
                    self._cond.wait()
                if self._closed and not self._buf:
                    return
                if self.linger_s > 0 and len(self._buf) < self.MAX_BATCH \
                        and not any("deadline_s" in e for e, _ in self._buf):
                    self._cond.wait(self.linger_s)
                batch = self._buf[:self.MAX_BATCH]
                del self._buf[:self.MAX_BATCH]
            self._flush(batch)

    def _flush(self, batch: List[Tuple[Dict, "Future[str]"]]) -> None:
        self.flushes += 1
        self.entries += len(batch)
        envelope = wire.request_envelope(
            "submit_coalesced", {"entries": [e for e, _ in batch]})
        try:
            body = self._client._call("POST", "/v1/submit_coalesced",
                                      envelope)
            outcomes = body["outcomes"]
            if len(outcomes) != len(batch):
                raise GatewayError(
                    ErrorCode.INTERNAL,
                    f"coalesced submit returned {len(outcomes)} outcomes "
                    f"for {len(batch)} entries")
        except Exception as e:                             # noqa: BLE001
            for _, fut in batch:
                if not fut.done():
                    fut.set_exception(e)
            return
        for (_, fut), out in zip(batch, outcomes):
            if fut.done():
                continue
            if "ticket" in out:
                fut.set_result(out["ticket"])
            else:
                err = WireError.from_wire(out.get("error") or {})
                fut.set_exception(GatewayError(err.code, err.message,
                                               err.detail))


class _ResultMux:
    """Shared completion waiter over ``/v1/poll_coalesced``: every thread
    blocked in :meth:`ControlPlaneClient.invoke_coalesced` parks a Future
    here, and ONE poller thread carries all outstanding tickets in a
    single long-poll frame per round — N concurrent waiters cost one wire
    round-trip per completion wave, not N polling loops."""

    POLL_ROUND_S = 5.0

    def __init__(self, client: "ControlPlaneClient"):
        self._client = client
        self._lock = threading.Lock()
        self._waiting: Dict[str, Future] = {}
        self._thread: Optional[threading.Thread] = None

    def register(self, ticket: str) -> Future:
        fut: Future = Future()
        with self._lock:
            self._waiting[ticket] = fut
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(
                    target=self._run, daemon=True,
                    name="phys-mcp-client-resultmux")
                self._thread.start()
        return fut

    def forget(self, ticket: str) -> None:
        with self._lock:
            self._waiting.pop(ticket, None)

    def _run(self) -> None:
        while True:
            # exit decision and registration share one lock: either this
            # pass sees a fresh ticket, or register() sees the cleared
            # thread slot and starts a successor — never neither
            with self._lock:
                tickets = [t for t, f in self._waiting.items()
                           if not f.done()]
                if not tickets:
                    self._thread = None
                    return
            try:
                outcomes = self._client.poll_coalesced(
                    tickets, wait_s=self.POLL_ROUND_S)
            except Exception as e:                         # noqa: BLE001
                # the plane itself is unreachable: fail every waiter —
                # they own retry policy, not this loop
                with self._lock:
                    failed = [self._waiting.pop(t) for t in tickets
                              if t in self._waiting]
                for fut in failed:
                    if not fut.done():
                        fut.set_exception(e)
                continue
            for out in outcomes:
                if out.get("state") == "pending":
                    continue
                with self._lock:
                    fut = self._waiting.pop(out.get("ticket"), None)
                if fut is not None and not fut.done():
                    fut.set_result(out)


class ControlPlaneClient:
    """One remote control plane, addressed by gateway URL.

    ``codec="binary"`` negotiates the compact v1.2 envelope framing both
    ways; the default ``"json"`` client is wire-identical to v1.1.
    ``coalesce_linger_s`` tunes the micro-batching buffer (0 = flush
    immediately, rely on group commit for batching)."""

    #: most keep-alive sockets the per-thread pool retains; LRU beyond
    #: this is closed (its owner transparently reconnects on next use)
    MAX_POOLED_CONNS = 32

    def __init__(self, url: str, timeout_s: float = 30.0,
                 api_key: Optional[str] = None, codec: str = "json",
                 coalesce_linger_s: float = 0.0):
        if codec not in ("json", "binary"):
            raise ValueError(f"codec must be 'json' or 'binary', not "
                             f"{codec!r}")
        self.url = url.rstrip("/")
        parsed = urllib.parse.urlparse(self.url)
        self._host = parsed.hostname or "127.0.0.1"
        self._port = parsed.port or 80
        self.timeout_s = timeout_s
        self.api_key = api_key
        self.codec = codec
        self._binary = codec == "binary"
        # persistent keep-alive connection per calling thread: control-plane
        # messages are small, so connection setup would dominate the wire
        # control path (connections are not thread-safe).  The pool is
        # keyed by thread ident, LRU-ordered, and bounded: dead owners are
        # reaped on every lookup, live victims just lose their socket (the
        # conn reconnects transparently on next use).
        self._pool: "OrderedDict[int, Tuple[threading.Thread, _WireConn]]" = OrderedDict()  # noqa: E501
        self._pool_lock = threading.Lock()
        self._coalescer = _Coalescer(self, linger_s=coalesce_linger_s)
        self._mux = _ResultMux(self)

    # -- transport ------------------------------------------------------------
    def _conn(self, timeout_s: float) -> _WireConn:
        ident = threading.get_ident()
        with self._pool_lock:
            entry = self._pool.get(ident)
            if entry is None:
                conn = _WireConn(self._host, self._port, timeout_s)
                self._pool[ident] = (threading.current_thread(), conn)
            else:
                conn = entry[1]
                current = threading.current_thread()
                if entry[0] is not current:
                    # the OS recycled a dead thread's ident: re-own the
                    # slot (else a reap sees a "dead owner" and closes the
                    # conn mid-call) and drop the inherited socket rather
                    # than trust another thread's leftover wire state
                    conn.close()
                    self._pool[ident] = (current, conn)
                self._pool.move_to_end(ident)
                conn.timeout = timeout_s
            self._reap_locked(ident)
        return conn

    def _reap_locked(self, current_ident: int) -> None:
        """Close connections whose owning thread exited, then LRU-evict
        down to the cap (skipping the caller's and any in-flight conns —
        closing those mid-request would turn pool hygiene into spurious
        PLANE_UNAVAILABLE errors)."""
        dead = [i for i, (t, _) in self._pool.items()
                if i != current_ident and not t.is_alive()]
        for i in dead:
            _, conn = self._pool.pop(i)
            try:
                conn.close()
            except Exception:                              # noqa: BLE001
                pass
        while len(self._pool) > self.MAX_POOLED_CONNS:
            victim = next((i for i, (_, c) in self._pool.items()
                           if i != current_ident and not c.in_flight), None)
            if victim is None:
                break
            _, conn = self._pool.pop(victim)
            try:
                conn.close()
            except Exception:                              # noqa: BLE001
                pass

    def _drop_conn(self) -> None:
        with self._pool_lock:
            entry = self._pool.pop(threading.get_ident(), None)
        if entry is not None:
            try:
                entry[1].close()
            except Exception:                              # noqa: BLE001
                pass

    def close(self) -> None:
        """Release pooled sockets and background coalescing threads.  The
        client keeps working after close (new connections are created on
        demand); this just returns resources eagerly."""
        self._coalescer.close()
        with self._pool_lock:
            entries = list(self._pool.values())
            self._pool.clear()
        for _, conn in entries:
            try:
                conn.close()
            except Exception:                              # noqa: BLE001
                pass

    def _call(self, method: str, path: str,
              envelope: Optional[Dict] = None,
              timeout_s: Optional[float] = None) -> Dict:
        if envelope is not None:
            data, ctype = wire.encode_envelope(envelope, self._binary)
        else:
            data, ctype = None, None
        headers = self._headers(ctype)
        payload = None
        # one retry on a STALE keep-alive connection (the server idle-closed
        # between calls), but only when a re-send cannot double-execute:
        # send-phase failures (the request provably never left), or a
        # RemoteDisconnected on an idempotent GET.  A POST that was already
        # sent is NEVER retried — the server may be executing that task on
        # physical hardware — and a timeout awaiting a slow response is a
        # timeout, not a license to re-send.
        for attempt in (0, 1):
            conn = self._conn(timeout_s or self.timeout_s)
            fresh = conn.sock is None
            sent = False
            conn.in_flight = True
            try:
                conn.request(method, path, data, headers)
                sent = True
                _status, rhdrs, raw = conn.getresponse()
                payload = wire.decode_envelope(raw, rhdrs.get("content-type"))
                break
            except (http.client.HTTPException, ConnectionError,
                    socket.timeout, TimeoutError, OSError) as e:
                self._drop_conn()
                retriable = (not sent) or (
                    method == "GET"
                    and isinstance(e, http.client.RemoteDisconnected))
                if fresh or attempt == 1 or not retriable:
                    raise GatewayError(
                        ErrorCode.PLANE_UNAVAILABLE,
                        f"control plane at {self.url} unreachable: "
                        f"{e!r}") from e
            finally:
                conn.in_flight = False
        try:
            return wire.parse_response(payload)
        except ControlPlaneError as e:
            raise GatewayError(e.code, e.message, e.detail) from None

    def _headers(self, content_type: Optional[str] = None) -> Dict[str, str]:
        headers = {
            "Content-Type": content_type or wire.JSON_CONTENT_TYPE,
            # response codec negotiation is per-request: the server answers
            # JSON unless this explicitly asks for the binary framing
            "Accept": (wire.BINARY_CONTENT_TYPE if self._binary
                       else wire.JSON_CONTENT_TYPE),
        }
        if self.api_key is not None:
            headers["Authorization"] = f"Bearer {self.api_key}"
        return headers

    @staticmethod
    def _qs(params: Dict) -> str:
        q = {k: v for k, v in params.items() if v is not None}
        return f"?{urllib.parse.urlencode(q)}" if q else ""

    # -- read surface ---------------------------------------------------------
    def health(self) -> Dict:
        return self._call("GET", "/v1/health")

    def topology(self) -> Dict:
        """Plane identity + federation reachability: ``{plane, plane_id,
        children, reachable, registry_epoch, resources}``.  Federation uses
        this for cycle detection before registering a child plane."""
        return self._call("GET", "/v1/topology")

    def discover(self, **filters) -> List[ResourceDescriptor]:
        body = self._call("GET", f"/v1/discover{self._qs(filters)}")
        return [wire.descriptor_from_wire(d) for d in body["descriptors"]]

    def describe(self, resource_id: str) -> Dict:
        body = self._call("GET", f"/v1/describe/{resource_id}")
        body["descriptor"] = wire.descriptor_from_wire(body["descriptor"])
        return body

    def twin(self, resource_id: str) -> Dict:
        return self._call("GET", f"/v1/twin/{resource_id}")["twin"]

    def telemetry(self, cursor: int = 0, timeout_s: float = 0.0,
                  resource: Optional[str] = None,
                  limit: Optional[int] = None) -> Dict:
        """Long-poll the plane's telemetry log: returns ``{events,
        next_cursor, dropped}``; pass ``next_cursor`` back to resume."""
        qs = self._qs({"cursor": cursor, "timeout_s": timeout_s,
                       "resource": resource, "limit": limit})
        return self._call("GET", f"/v1/telemetry{qs}",
                          timeout_s=self.timeout_s + timeout_s)

    def stream(self, cursor: Optional[int] = None,
               resources: Optional[Iterable[str]] = None,
               kinds: Optional[Iterable[str]] = None,
               min_severity: str = "debug",
               heartbeat_s: float = 10.0,
               max_s: Optional[float] = None,
               include_control: bool = False) -> TelemetryStream:
        """Open ONE server-push telemetry subscription (``/v1/stream``) —
        the streaming replacement for a :meth:`telemetry` polling loop.

        Returns a :class:`~repro.gateway.stream.TelemetryStream` iterator
        of event dicts; events carry the same ``seq`` as the cursor
        endpoint, so zero-loss delivery is auditable and a broken stream
        resumes from ``stream.cursor``.  ``cursor=None`` (default) follows
        only NEW events; pass an explicit cursor to backfill from the ring.

        The subscription holds a dedicated connection (the per-thread
        keep-alive pool is never blocked by it).  The socket read timeout
        is tied to the heartbeat interval, so a silently-dead plane
        surfaces as a broken stream within ~3 heartbeats.
        """
        filt = StreamFilter(
            resources=frozenset(resources) if resources else None,
            kinds=frozenset(kinds) if kinds else None,
            min_severity=min_severity)
        params: Dict = dict(filt.to_query())
        if cursor is not None:
            params["cursor"] = cursor
        params["heartbeat_s"] = heartbeat_s
        if max_s is not None:
            params["max_s"] = max_s
        conn = _NoDelayHTTPConnection(
            self._host, self._port, timeout=max(heartbeat_s * 3.0, 5.0))
        try:
            conn.request("GET", f"/v1/stream{self._qs(params)}",
                         headers=self._headers())
            resp = conn.getresponse()
            if resp.status != 200:
                payload = wire.decode_envelope(
                    resp.read(), resp.getheader("Content-Type"))
                conn.close()
                wire.parse_response(payload)   # raises the transported error
                raise GatewayError(ErrorCode.INTERNAL,
                                   f"stream refused with HTTP {resp.status}")
        except (http.client.HTTPException, ConnectionError, socket.timeout,
                TimeoutError, OSError) as e:
            conn.close()
            raise GatewayError(
                ErrorCode.PLANE_UNAVAILABLE,
                f"control plane at {self.url} unreachable: {e!r}") from e
        except ControlPlaneError as e:
            raise GatewayError(e.code, e.message, e.detail) from None
        return TelemetryStream(conn, resp, include_control=include_control)

    # -- execution ------------------------------------------------------------
    @staticmethod
    def _outcome(body: Dict) -> Tuple[InvocationResult, OrchestrationTrace]:
        return (wire.result_from_wire(body["result"]),
                wire.trace_from_wire(body["trace"]))

    #: saturation retries before giving up (per invoke call)
    BACKPRESSURE_RETRIES = 2

    @staticmethod
    def _budget_deadline(task: TaskRequest,
                         deadline_s: Optional[float]) -> Optional[float]:
        budget_s = deadline_s if deadline_s is not None else (
            task.latency_budget_ms / 1e3
            if task.latency_budget_ms is not None else None)
        return (time.monotonic() + budget_s) if budget_s is not None else None

    @staticmethod
    def _backoff_delay(e: GatewayError, attempt: int, retries: int,
                       give_up_at: Optional[float]) -> Optional[float]:
        """Jittered QUEUE_SATURATED backoff, or None when the error should
        propagate (not saturation, retries exhausted, or honoring the hint
        would blow the task's own deadline budget)."""
        hint = e.detail.get("retry_after_s")
        if (e.code is not ErrorCode.QUEUE_SATURATED or hint is None
                or attempt >= retries):
            return None
        delay = float(hint) * (0.5 + random.random())       # 0.5x–1.5x
        if give_up_at is not None \
                and time.monotonic() + delay > give_up_at:
            return None                # honoring the hint would blow budget
        return delay

    def invoke(self, task: TaskRequest,
               deadline_s: Optional[float] = None,
               backpressure_retries: Optional[int] = None
               ) -> Tuple[InvocationResult, OrchestrationTrace]:
        """Synchronous remote execution; same contract as
        ``Orchestrator.submit`` (rejections raise :class:`GatewayError`
        with the taxonomy code + trace instead of returning).

        ``QUEUE_SATURATED`` rejections carrying the plane's
        ``retry_after_s`` hint are retried with jittered backoff — a
        saturated rejection means the task never ran, so a re-send cannot
        double-execute.  Retries stop when the hint would overrun the
        task's own deadline budget (``deadline_s``, else the task's
        latency budget), so backoff never turns a saturation error into a
        silent deadline miss.  ``backpressure_retries=0`` disables."""
        envelope = wire.request_envelope(
            "invoke", {"task": wire.task_to_wire(task),
                       "deadline_s": deadline_s})
        timeout = self.timeout_s + (deadline_s or 0.0)
        retries = (self.BACKPRESSURE_RETRIES if backpressure_retries is None
                   else backpressure_retries)
        give_up_at = self._budget_deadline(task, deadline_s)
        attempt = 0
        while True:
            try:
                return self._outcome(self._call("POST", "/v1/invoke",
                                                envelope, timeout_s=timeout))
            except GatewayError as e:
                delay = self._backoff_delay(e, attempt, retries, give_up_at)
                if delay is None:
                    raise
                attempt += 1
                time.sleep(delay)

    def submit(self, task: TaskRequest,
               deadline_s: Optional[float] = None) -> str:
        """Async submission; returns a ticket for :meth:`poll` /
        :meth:`result`."""
        envelope = wire.request_envelope(
            "submit", {"task": wire.task_to_wire(task),
                       "deadline_s": deadline_s})
        return self._call("POST", "/v1/submit", envelope)["ticket"]

    def submit_many(self, tasks: Sequence[TaskRequest],
                    deadline_s: Optional[float] = None) -> List[str]:
        envelope = wire.request_envelope(
            "submit_many", {"tasks": [wire.task_to_wire(t) for t in tasks],
                            "deadline_s": deadline_s})
        return self._call("POST", "/v1/submit_many", envelope)["tickets"]

    def poll(self, ticket: str, wait_s: float = 0.0
             ) -> Optional[Tuple[InvocationResult, OrchestrationTrace]]:
        """One poll round: None while pending, the outcome once resolved
        (rejections raise, same as :meth:`invoke`)."""
        qs = self._qs({"wait_s": wait_s or None})
        body = self._call("GET", f"/v1/poll/{ticket}{qs}",
                          timeout_s=self.timeout_s + wait_s)
        if body.get("state") == "pending":
            return None
        return self._outcome(body)

    def result(self, ticket: str, timeout_s: float = 60.0
               ) -> Tuple[InvocationResult, OrchestrationTrace]:
        """Await a ticket via bounded long-poll rounds."""
        deadline = time.monotonic() + timeout_s
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise GatewayError(ErrorCode.DEADLINE,
                                   f"ticket {ticket} still pending after "
                                   f"{timeout_s}s")
            out = self.poll(ticket, wait_s=min(remaining, 5.0))
            if out is not None:
                return out

    # -- coalesced execution (v1.2) -------------------------------------------
    def submit_coalesced(self, task: TaskRequest,
                         deadline_s: Optional[float] = None) -> str:
        """Async submission through the micro-batching buffer: concurrent
        callers share one ``/v1/submit_coalesced`` wire frame.  Returns a
        ticket usable with :meth:`poll` / :meth:`result` /
        :meth:`poll_coalesced` exactly like :meth:`submit`."""
        fut = self._coalescer.enqueue(task, deadline_s)
        try:
            return fut.result(timeout=self.timeout_s + 30.0)
        except (FutureTimeout, TimeoutError):
            raise GatewayError(
                ErrorCode.PLANE_UNAVAILABLE,
                f"coalesced submit to {self.url} stalled") from None

    def poll_coalesced(self, tickets: Sequence[str],
                       wait_s: float = 0.0) -> List[Dict]:
        """One wire round-trip reporting the state of N tickets.  Returns
        index-aligned outcome dicts: ``{"ticket", "state": "pending"}`` or
        ``{"ticket", "state": "done", "ok", "result"/"error", ...}`` —
        resolved tickets are delivered-once, exactly like :meth:`poll`."""
        envelope = wire.request_envelope(
            "poll_coalesced", {"tickets": list(tickets), "wait_s": wait_s})
        body = self._call("POST", "/v1/poll_coalesced", envelope,
                          timeout_s=self.timeout_s + wait_s)
        return body["outcomes"]

    def _coalesced_result(self, out: Dict
                          ) -> Tuple[InvocationResult, OrchestrationTrace]:
        if out.get("ok"):
            return self._outcome(out)
        err = WireError.from_wire(out.get("error") or {})
        raise GatewayError(err.code, err.message, err.detail)

    def invoke_coalesced(self, task: TaskRequest,
                         deadline_s: Optional[float] = None,
                         backpressure_retries: Optional[int] = None
                         ) -> Tuple[InvocationResult, OrchestrationTrace]:
        """Same contract as :meth:`invoke`, but both wire legs are shared:
        the submit rides the coalescing buffer and the completion wait
        rides the client-wide :class:`_ResultMux` long-poll — N concurrent
        federated forwards cost ~2 round-trips, not 2N.  Saturation backoff
        behaves exactly like :meth:`invoke`."""
        retries = (self.BACKPRESSURE_RETRIES if backpressure_retries is None
                   else backpressure_retries)
        give_up_at = self._budget_deadline(task, deadline_s)
        wait_budget = self.timeout_s + (deadline_s or 0.0)
        attempt = 0
        while True:
            try:
                ticket = self.submit_coalesced(task, deadline_s)
                fut = self._mux.register(ticket)
                try:
                    out = fut.result(timeout=wait_budget)
                except (FutureTimeout, TimeoutError):
                    self._mux.forget(ticket)
                    raise GatewayError(
                        ErrorCode.DEADLINE,
                        f"ticket {ticket} still pending after "
                        f"{wait_budget}s") from None
                return self._coalesced_result(out)
            except GatewayError as e:
                delay = self._backoff_delay(e, attempt, retries, give_up_at)
                if delay is None:
                    raise
                attempt += 1
                time.sleep(delay)
