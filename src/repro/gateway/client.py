"""ControlPlaneClient: typed SDK over the phys-MCP wire protocol.

The client gives remote callers the SAME types the in-process API returns —
``discover()`` yields real :class:`ResourceDescriptor` objects (rebuilt
through ``from_dict``, which is the descriptor-portability claim made
executable), ``invoke()`` returns the familiar ``(InvocationResult,
OrchestrationTrace)`` pair — so code written against an ``Orchestrator``
ports to a remote plane by swapping the object it calls.

Failures raise :class:`GatewayError` carrying the structured taxonomy code
plus the server's detail (full trace, twin ``invalidation_reason``), never
a bare HTTP error.

Backpressure: ``QUEUE_SATURATED`` rejections carry the plane's live
``retry_after_s`` hint; :meth:`ControlPlaneClient.invoke` honors it with
jittered backoff (bounded by the task's own deadline budget) instead of
hammering a saturated plane.  Auth: construct with ``api_key=`` to send
``Authorization: Bearer`` on every request (keyed gateways refuse
credential-less planes with ``UNAUTHORIZED``).  Streaming:
:meth:`ControlPlaneClient.stream` opens one server-push subscription
(``/v1/stream``) that replaces a whole polling-cursor loop.
"""
from __future__ import annotations

import http.client
import random
import socket
import threading
import time
import urllib.parse
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.descriptors import ResourceDescriptor
from repro.core.errors import ControlPlaneError, ErrorCode
from repro.core.invocation import InvocationResult
from repro.core.orchestrator import OrchestrationTrace
from repro.core.tasks import TaskRequest
from repro.gateway import protocol as wire
from repro.gateway.stream import StreamFilter, TelemetryStream


class GatewayError(ControlPlaneError):
    """A wire request failed; ``code``/``message``/``detail`` mirror the
    server's structured error (``detail`` may carry the full trace and a
    twin's ``invalidation_reason``)."""

    @property
    def trace(self) -> Optional[OrchestrationTrace]:
        t = self.detail.get("trace")
        return wire.trace_from_wire(t) if t else None

    @property
    def invalidation_reason(self) -> Optional[str]:
        return self.detail.get("invalidation_reason")


class ControlPlaneClient:
    """One remote control plane, addressed by gateway URL."""

    def __init__(self, url: str, timeout_s: float = 30.0,
                 api_key: Optional[str] = None):
        self.url = url.rstrip("/")
        parsed = urllib.parse.urlparse(self.url)
        self._host = parsed.hostname or "127.0.0.1"
        self._port = parsed.port or 80
        self.timeout_s = timeout_s
        self.api_key = api_key
        # persistent keep-alive connection per calling thread: control-plane
        # messages are small, so connection setup would dominate the wire
        # control path (http.client connections are not thread-safe)
        self._local = threading.local()

    # -- transport ------------------------------------------------------------
    def _conn(self, timeout_s: float) -> http.client.HTTPConnection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = http.client.HTTPConnection(self._host, self._port,
                                              timeout=timeout_s)
            self._local.conn = conn
        else:
            conn.timeout = timeout_s
            if conn.sock is not None:
                conn.sock.settimeout(timeout_s)
        return conn

    def _drop_conn(self) -> None:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            conn.close()
        self._local.conn = None

    def _call(self, method: str, path: str,
              envelope: Optional[Dict] = None,
              timeout_s: Optional[float] = None) -> Dict:
        data = wire.dumps(envelope) if envelope is not None else None
        headers = self._headers()
        payload = None
        # one retry on a STALE keep-alive connection (the server idle-closed
        # between calls), but only when a re-send cannot double-execute:
        # send-phase failures (the request provably never left), or a
        # RemoteDisconnected on an idempotent GET.  A POST that was already
        # sent is NEVER retried — the server may be executing that task on
        # physical hardware — and a timeout awaiting a slow response is a
        # timeout, not a license to re-send.
        for attempt in (0, 1):
            conn = self._conn(timeout_s or self.timeout_s)
            fresh = conn.sock is None
            sent = False
            try:
                conn.request(method, path, body=data, headers=headers)
                sent = True
                resp = conn.getresponse()
                payload = wire.loads(resp.read())
                break
            except (http.client.HTTPException, ConnectionError,
                    socket.timeout, TimeoutError, OSError) as e:
                self._drop_conn()
                retriable = (not sent) or (
                    method == "GET"
                    and isinstance(e, http.client.RemoteDisconnected))
                if fresh or attempt == 1 or not retriable:
                    raise GatewayError(
                        ErrorCode.PLANE_UNAVAILABLE,
                        f"control plane at {self.url} unreachable: "
                        f"{e!r}") from e
        try:
            return wire.parse_response(payload)
        except ControlPlaneError as e:
            raise GatewayError(e.code, e.message, e.detail) from None

    def _headers(self) -> Dict[str, str]:
        headers = {"Content-Type": "application/json"}
        if self.api_key is not None:
            headers["Authorization"] = f"Bearer {self.api_key}"
        return headers

    @staticmethod
    def _qs(params: Dict) -> str:
        q = {k: v for k, v in params.items() if v is not None}
        return f"?{urllib.parse.urlencode(q)}" if q else ""

    # -- read surface ---------------------------------------------------------
    def health(self) -> Dict:
        return self._call("GET", "/v1/health")

    def topology(self) -> Dict:
        """Plane identity + federation reachability: ``{plane, plane_id,
        children, reachable, registry_epoch, resources}``.  Federation uses
        this for cycle detection before registering a child plane."""
        return self._call("GET", "/v1/topology")

    def discover(self, **filters) -> List[ResourceDescriptor]:
        body = self._call("GET", f"/v1/discover{self._qs(filters)}")
        return [wire.descriptor_from_wire(d) for d in body["descriptors"]]

    def describe(self, resource_id: str) -> Dict:
        body = self._call("GET", f"/v1/describe/{resource_id}")
        body["descriptor"] = wire.descriptor_from_wire(body["descriptor"])
        return body

    def twin(self, resource_id: str) -> Dict:
        return self._call("GET", f"/v1/twin/{resource_id}")["twin"]

    def telemetry(self, cursor: int = 0, timeout_s: float = 0.0,
                  resource: Optional[str] = None,
                  limit: Optional[int] = None) -> Dict:
        """Long-poll the plane's telemetry log: returns ``{events,
        next_cursor, dropped}``; pass ``next_cursor`` back to resume."""
        qs = self._qs({"cursor": cursor, "timeout_s": timeout_s,
                       "resource": resource, "limit": limit})
        return self._call("GET", f"/v1/telemetry{qs}",
                          timeout_s=self.timeout_s + timeout_s)

    def stream(self, cursor: Optional[int] = None,
               resources: Optional[Iterable[str]] = None,
               kinds: Optional[Iterable[str]] = None,
               min_severity: str = "debug",
               heartbeat_s: float = 10.0,
               max_s: Optional[float] = None,
               include_control: bool = False) -> TelemetryStream:
        """Open ONE server-push telemetry subscription (``/v1/stream``) —
        the streaming replacement for a :meth:`telemetry` polling loop.

        Returns a :class:`~repro.gateway.stream.TelemetryStream` iterator
        of event dicts; events carry the same ``seq`` as the cursor
        endpoint, so zero-loss delivery is auditable and a broken stream
        resumes from ``stream.cursor``.  ``cursor=None`` (default) follows
        only NEW events; pass an explicit cursor to backfill from the ring.

        The subscription holds a dedicated connection (the per-thread
        keep-alive pool is never blocked by it).  The socket read timeout
        is tied to the heartbeat interval, so a silently-dead plane
        surfaces as a broken stream within ~3 heartbeats.
        """
        filt = StreamFilter(
            resources=frozenset(resources) if resources else None,
            kinds=frozenset(kinds) if kinds else None,
            min_severity=min_severity)
        params: Dict = dict(filt.to_query())
        if cursor is not None:
            params["cursor"] = cursor
        params["heartbeat_s"] = heartbeat_s
        if max_s is not None:
            params["max_s"] = max_s
        conn = http.client.HTTPConnection(
            self._host, self._port, timeout=max(heartbeat_s * 3.0, 5.0))
        try:
            conn.request("GET", f"/v1/stream{self._qs(params)}",
                         headers=self._headers())
            resp = conn.getresponse()
            if resp.status != 200:
                payload = wire.loads(resp.read())
                conn.close()
                wire.parse_response(payload)   # raises the transported error
                raise GatewayError(ErrorCode.INTERNAL,
                                   f"stream refused with HTTP {resp.status}")
        except (http.client.HTTPException, ConnectionError, socket.timeout,
                TimeoutError, OSError) as e:
            conn.close()
            raise GatewayError(
                ErrorCode.PLANE_UNAVAILABLE,
                f"control plane at {self.url} unreachable: {e!r}") from e
        except ControlPlaneError as e:
            raise GatewayError(e.code, e.message, e.detail) from None
        return TelemetryStream(conn, resp, include_control=include_control)

    # -- execution ------------------------------------------------------------
    @staticmethod
    def _outcome(body: Dict) -> Tuple[InvocationResult, OrchestrationTrace]:
        return (wire.result_from_wire(body["result"]),
                wire.trace_from_wire(body["trace"]))

    #: saturation retries before giving up (per invoke call)
    BACKPRESSURE_RETRIES = 2

    def invoke(self, task: TaskRequest,
               deadline_s: Optional[float] = None,
               backpressure_retries: Optional[int] = None
               ) -> Tuple[InvocationResult, OrchestrationTrace]:
        """Synchronous remote execution; same contract as
        ``Orchestrator.submit`` (rejections raise :class:`GatewayError`
        with the taxonomy code + trace instead of returning).

        ``QUEUE_SATURATED`` rejections carrying the plane's
        ``retry_after_s`` hint are retried with jittered backoff — a
        saturated rejection means the task never ran, so a re-send cannot
        double-execute.  Retries stop when the hint would overrun the
        task's own deadline budget (``deadline_s``, else the task's
        latency budget), so backoff never turns a saturation error into a
        silent deadline miss.  ``backpressure_retries=0`` disables."""
        envelope = wire.request_envelope(
            "invoke", {"task": wire.task_to_wire(task),
                       "deadline_s": deadline_s})
        timeout = self.timeout_s + (deadline_s or 0.0)
        retries = (self.BACKPRESSURE_RETRIES if backpressure_retries is None
                   else backpressure_retries)
        budget_s = deadline_s if deadline_s is not None else (
            task.latency_budget_ms / 1e3
            if task.latency_budget_ms is not None else None)
        give_up_at = (time.monotonic() + budget_s) if budget_s is not None \
            else None
        attempt = 0
        while True:
            try:
                return self._outcome(self._call("POST", "/v1/invoke",
                                                envelope, timeout_s=timeout))
            except GatewayError as e:
                hint = e.detail.get("retry_after_s")
                if (e.code is not ErrorCode.QUEUE_SATURATED or hint is None
                        or attempt >= retries):
                    raise
                delay = float(hint) * (0.5 + random.random())  # 0.5x–1.5x
                if give_up_at is not None \
                        and time.monotonic() + delay > give_up_at:
                    raise              # honoring the hint would blow budget
                attempt += 1
                time.sleep(delay)

    def submit(self, task: TaskRequest,
               deadline_s: Optional[float] = None) -> str:
        """Async submission; returns a ticket for :meth:`poll` /
        :meth:`result`."""
        envelope = wire.request_envelope(
            "submit", {"task": wire.task_to_wire(task),
                       "deadline_s": deadline_s})
        return self._call("POST", "/v1/submit", envelope)["ticket"]

    def submit_many(self, tasks: Sequence[TaskRequest],
                    deadline_s: Optional[float] = None) -> List[str]:
        envelope = wire.request_envelope(
            "submit_many", {"tasks": [wire.task_to_wire(t) for t in tasks],
                            "deadline_s": deadline_s})
        return self._call("POST", "/v1/submit_many", envelope)["tickets"]

    def poll(self, ticket: str, wait_s: float = 0.0
             ) -> Optional[Tuple[InvocationResult, OrchestrationTrace]]:
        """One poll round: None while pending, the outcome once resolved
        (rejections raise, same as :meth:`invoke`)."""
        qs = self._qs({"wait_s": wait_s or None})
        body = self._call("GET", f"/v1/poll/{ticket}{qs}",
                          timeout_s=self.timeout_s + wait_s)
        if body.get("state") == "pending":
            return None
        return self._outcome(body)

    def result(self, ticket: str, timeout_s: float = 60.0
               ) -> Tuple[InvocationResult, OrchestrationTrace]:
        """Await a ticket via bounded long-poll rounds."""
        deadline = time.monotonic() + timeout_s
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise GatewayError(ErrorCode.DEADLINE,
                                   f"ticket {ticket} still pending after "
                                   f"{timeout_s}s")
            out = self.poll(ticket, wait_s=min(remaining, 5.0))
            if out is not None:
                return out
