"""ControlPlaneClient: typed SDK over the phys-MCP wire protocol.

The client gives remote callers the SAME types the in-process API returns —
``discover()`` yields real :class:`ResourceDescriptor` objects (rebuilt
through ``from_dict``, which is the descriptor-portability claim made
executable), ``invoke()`` returns the familiar ``(InvocationResult,
OrchestrationTrace)`` pair — so code written against an ``Orchestrator``
ports to a remote plane by swapping the object it calls.

Failures raise :class:`GatewayError` carrying the structured taxonomy code
plus the server's detail (full trace, twin ``invalidation_reason``), never
a bare HTTP error.
"""
from __future__ import annotations

import http.client
import socket
import threading
import time
import urllib.parse
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.descriptors import ResourceDescriptor
from repro.core.errors import ControlPlaneError, ErrorCode
from repro.core.invocation import InvocationResult
from repro.core.orchestrator import OrchestrationTrace
from repro.core.tasks import TaskRequest
from repro.gateway import protocol as wire


class GatewayError(ControlPlaneError):
    """A wire request failed; ``code``/``message``/``detail`` mirror the
    server's structured error (``detail`` may carry the full trace and a
    twin's ``invalidation_reason``)."""

    @property
    def trace(self) -> Optional[OrchestrationTrace]:
        t = self.detail.get("trace")
        return wire.trace_from_wire(t) if t else None

    @property
    def invalidation_reason(self) -> Optional[str]:
        return self.detail.get("invalidation_reason")


class ControlPlaneClient:
    """One remote control plane, addressed by gateway URL."""

    def __init__(self, url: str, timeout_s: float = 30.0):
        self.url = url.rstrip("/")
        parsed = urllib.parse.urlparse(self.url)
        self._host = parsed.hostname or "127.0.0.1"
        self._port = parsed.port or 80
        self.timeout_s = timeout_s
        # persistent keep-alive connection per calling thread: control-plane
        # messages are small, so connection setup would dominate the wire
        # control path (http.client connections are not thread-safe)
        self._local = threading.local()

    # -- transport ------------------------------------------------------------
    def _conn(self, timeout_s: float) -> http.client.HTTPConnection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = http.client.HTTPConnection(self._host, self._port,
                                              timeout=timeout_s)
            self._local.conn = conn
        else:
            conn.timeout = timeout_s
            if conn.sock is not None:
                conn.sock.settimeout(timeout_s)
        return conn

    def _drop_conn(self) -> None:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            conn.close()
        self._local.conn = None

    def _call(self, method: str, path: str,
              envelope: Optional[Dict] = None,
              timeout_s: Optional[float] = None) -> Dict:
        data = wire.dumps(envelope) if envelope is not None else None
        headers = {"Content-Type": "application/json"}
        payload = None
        # one retry on a STALE keep-alive connection (the server idle-closed
        # between calls), but only when a re-send cannot double-execute:
        # send-phase failures (the request provably never left), or a
        # RemoteDisconnected on an idempotent GET.  A POST that was already
        # sent is NEVER retried — the server may be executing that task on
        # physical hardware — and a timeout awaiting a slow response is a
        # timeout, not a license to re-send.
        for attempt in (0, 1):
            conn = self._conn(timeout_s or self.timeout_s)
            fresh = conn.sock is None
            sent = False
            try:
                conn.request(method, path, body=data, headers=headers)
                sent = True
                resp = conn.getresponse()
                payload = wire.loads(resp.read())
                break
            except (http.client.HTTPException, ConnectionError,
                    socket.timeout, TimeoutError, OSError) as e:
                self._drop_conn()
                retriable = (not sent) or (
                    method == "GET"
                    and isinstance(e, http.client.RemoteDisconnected))
                if fresh or attempt == 1 or not retriable:
                    raise GatewayError(
                        ErrorCode.PLANE_UNAVAILABLE,
                        f"control plane at {self.url} unreachable: "
                        f"{e!r}") from e
        try:
            return wire.parse_response(payload)
        except ControlPlaneError as e:
            raise GatewayError(e.code, e.message, e.detail) from None

    @staticmethod
    def _qs(params: Dict) -> str:
        q = {k: v for k, v in params.items() if v is not None}
        return f"?{urllib.parse.urlencode(q)}" if q else ""

    # -- read surface ---------------------------------------------------------
    def health(self) -> Dict:
        return self._call("GET", "/v1/health")

    def discover(self, **filters) -> List[ResourceDescriptor]:
        body = self._call("GET", f"/v1/discover{self._qs(filters)}")
        return [wire.descriptor_from_wire(d) for d in body["descriptors"]]

    def describe(self, resource_id: str) -> Dict:
        body = self._call("GET", f"/v1/describe/{resource_id}")
        body["descriptor"] = wire.descriptor_from_wire(body["descriptor"])
        return body

    def twin(self, resource_id: str) -> Dict:
        return self._call("GET", f"/v1/twin/{resource_id}")["twin"]

    def telemetry(self, cursor: int = 0, timeout_s: float = 0.0,
                  resource: Optional[str] = None,
                  limit: Optional[int] = None) -> Dict:
        """Long-poll the plane's telemetry log: returns ``{events,
        next_cursor, dropped}``; pass ``next_cursor`` back to resume."""
        qs = self._qs({"cursor": cursor, "timeout_s": timeout_s,
                       "resource": resource, "limit": limit})
        return self._call("GET", f"/v1/telemetry{qs}",
                          timeout_s=self.timeout_s + timeout_s)

    # -- execution ------------------------------------------------------------
    @staticmethod
    def _outcome(body: Dict) -> Tuple[InvocationResult, OrchestrationTrace]:
        return (wire.result_from_wire(body["result"]),
                wire.trace_from_wire(body["trace"]))

    def invoke(self, task: TaskRequest,
               deadline_s: Optional[float] = None
               ) -> Tuple[InvocationResult, OrchestrationTrace]:
        """Synchronous remote execution; same contract as
        ``Orchestrator.submit`` (rejections raise :class:`GatewayError`
        with the taxonomy code + trace instead of returning)."""
        envelope = wire.request_envelope(
            "invoke", {"task": wire.task_to_wire(task),
                       "deadline_s": deadline_s})
        timeout = self.timeout_s + (deadline_s or 0.0)
        return self._outcome(
            self._call("POST", "/v1/invoke", envelope, timeout_s=timeout))

    def submit(self, task: TaskRequest,
               deadline_s: Optional[float] = None) -> str:
        """Async submission; returns a ticket for :meth:`poll` /
        :meth:`result`."""
        envelope = wire.request_envelope(
            "submit", {"task": wire.task_to_wire(task),
                       "deadline_s": deadline_s})
        return self._call("POST", "/v1/submit", envelope)["ticket"]

    def submit_many(self, tasks: Sequence[TaskRequest],
                    deadline_s: Optional[float] = None) -> List[str]:
        envelope = wire.request_envelope(
            "submit_many", {"tasks": [wire.task_to_wire(t) for t in tasks],
                            "deadline_s": deadline_s})
        return self._call("POST", "/v1/submit_many", envelope)["tickets"]

    def poll(self, ticket: str, wait_s: float = 0.0
             ) -> Optional[Tuple[InvocationResult, OrchestrationTrace]]:
        """One poll round: None while pending, the outcome once resolved
        (rejections raise, same as :meth:`invoke`)."""
        qs = self._qs({"wait_s": wait_s or None})
        body = self._call("GET", f"/v1/poll/{ticket}{qs}",
                          timeout_s=self.timeout_s + wait_s)
        if body.get("state") == "pending":
            return None
        return self._outcome(body)

    def result(self, ticket: str, timeout_s: float = 60.0
               ) -> Tuple[InvocationResult, OrchestrationTrace]:
        """Await a ticket via bounded long-poll rounds."""
        deadline = time.monotonic() + timeout_s
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise GatewayError(ErrorCode.DEADLINE,
                                   f"ticket {ticket} still pending after "
                                   f"{timeout_s}s")
            out = self.poll(ticket, wait_s=min(remaining, 5.0))
            if out is not None:
                return out
