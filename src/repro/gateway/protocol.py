"""phys-MCP wire protocol v1: versioned envelopes + faithful wire types.

This module is the contract between a control plane and anything that talks
to it across a process boundary: the :class:`~repro.gateway.server.
ControlPlaneGateway` HTTP server, the :class:`~repro.gateway.client.
ControlPlaneClient` SDK, and the federation adapter
(:class:`~repro.substrates.remote_plane.RemotePlaneAdapter`).

Design rules:

- **Versioned** — every envelope carries ``protocol_version``; a plane
  refuses versions it does not speak with ``BAD_REQUEST`` instead of
  mis-parsing them.  Policy: additive body fields are a MINOR bump (old
  clients ignore them), removed/renamed fields or changed semantics are a
  MAJOR bump (the server refuses mismatched majors).
- **Faithful** — ``to_wire``/``from_wire`` round-trip exactly:
  ``TaskRequest`` keeps its payload and task id, descriptors rebuild all
  five nested specs, results/traces/snapshots survive the hop unchanged.
  The redacting forms (``TaskRequest.summary``) never cross the wire.
- **Structured errors** — failures travel as
  :class:`~repro.core.errors.WireError` (code from the closed
  :class:`~repro.core.errors.ErrorCode` taxonomy + prose + detail), never
  as bare strings, so a client can program against outcomes.
"""
from __future__ import annotations

import json
import struct as _struct
from typing import Any, Dict, Optional, Tuple

import numpy as np

# re-exported: the taxonomy lives in repro.core so the in-process control
# plane can consume it without importing the gateway layer
from repro.core.errors import (ControlPlaneError, ErrorCode,  # noqa: F401
                               WireError, classify_rejection)
from repro.core.descriptors import ResourceDescriptor
from repro.core.invocation import InvocationResult
from repro.core.orchestrator import OrchestrationTrace
from repro.core.tasks import TaskRequest
from repro.core.telemetry import RuntimeSnapshot

#: current protocol version (MAJOR.MINOR); see module docstring for policy.
#: 1.1 (MINOR, additive): ``plane_id`` on envelopes, multi-hop task budget
#: fields (``hop_budget``/``deadline_budget_ms``/``route``), the
#: ``/v1/stream`` + ``/v1/topology`` endpoints, ``retry_after_s`` backoff
#: hints on QUEUE_SATURATED errors, and per-event ``severity`` — 1.0 peers
#: ignore all of it and keep working.
#: 1.2 (MINOR, additive): the compact binary envelope codec
#: (``application/x-physmcp``, negotiated per request via ``Content-Type``
#: / ``Accept`` — JSON stays the default and the JSON wire form is
#: byte-for-byte what 1.1 produced), plus the coalesced execution
#: endpoints ``POST /v1/submit_coalesced`` (one round-trip carries N task
#: submissions, per-entry outcomes) and ``POST /v1/poll_coalesced`` (one
#: round-trip polls N tickets).  1.1 peers never see any of it.
PROTOCOL_VERSION = "1.2"
#: majors this implementation can parse
SUPPORTED_MAJORS = ("1",)


class ProtocolError(ControlPlaneError):
    """Malformed envelope / unsupported version (maps to BAD_REQUEST)."""

    def __init__(self, message: str, detail: Optional[Dict] = None):
        super().__init__(ErrorCode.BAD_REQUEST, message, detail)


def check_version(version: Optional[str]) -> None:
    if not version or version.split(".")[0] not in SUPPORTED_MAJORS:
        raise ProtocolError(
            f"unsupported protocol version {version!r} "
            f"(speaking {PROTOCOL_VERSION})",
            {"protocol_version": PROTOCOL_VERSION})


# ---------------------------------------------------------------------------
# envelopes


def request_envelope(kind: str, body: Dict,
                     plane_id: Optional[str] = None) -> Dict:
    env = {"protocol_version": PROTOCOL_VERSION, "kind": kind, "body": body}
    if plane_id is not None:
        env["plane_id"] = plane_id
    return env


def ok_envelope(kind: str, body: Dict,
                plane_id: Optional[str] = None) -> Dict:
    env = {"protocol_version": PROTOCOL_VERSION, "kind": kind, "ok": True,
           "body": body}
    if plane_id is not None:
        env["plane_id"] = plane_id
    return env


def error_envelope(kind: str, error: WireError,
                   plane_id: Optional[str] = None) -> Dict:
    env = {"protocol_version": PROTOCOL_VERSION, "kind": kind, "ok": False,
           "error": error.to_wire()}
    if plane_id is not None:
        env["plane_id"] = plane_id
    return env


def parse_request(envelope: Dict, expect_kind: Optional[str] = None) -> Dict:
    """Validate an incoming request envelope; returns its body."""
    if not isinstance(envelope, dict):
        raise ProtocolError("request envelope must be a JSON object")
    check_version(envelope.get("protocol_version"))
    if expect_kind is not None and envelope.get("kind") != expect_kind:
        raise ProtocolError(
            f"expected kind {expect_kind!r}, got {envelope.get('kind')!r}")
    body = envelope.get("body")
    if not isinstance(body, dict):
        raise ProtocolError("request envelope has no body object")
    return body


def parse_response(envelope: Dict) -> Dict:
    """Validate a response envelope; returns the body or raises the
    transported :class:`ControlPlaneError`."""
    if not isinstance(envelope, dict):
        raise ProtocolError("response envelope must be a JSON object")
    check_version(envelope.get("protocol_version"))
    if not envelope.get("ok", False):
        err = WireError.from_wire(envelope.get("error") or {})
        raise ControlPlaneError.from_wire_error(err)
    return envelope.get("body") or {}


# ---------------------------------------------------------------------------
# wire converters (thin, named indirection so protocol evolution has one
# place to live; the faithful implementations sit on the types themselves)


def task_to_wire(task: TaskRequest) -> Dict:
    return task.to_wire()


def task_from_wire(d: Dict) -> TaskRequest:
    return TaskRequest.from_wire(d)


def descriptor_to_wire(desc: ResourceDescriptor) -> Dict:
    return desc.to_dict()


def descriptor_from_wire(d: Dict) -> ResourceDescriptor:
    return ResourceDescriptor.from_dict(d)


def result_to_wire(result: InvocationResult) -> Dict:
    return result.to_wire()


def result_from_wire(d: Dict) -> InvocationResult:
    return InvocationResult.from_wire(d)


def trace_to_wire(trace: OrchestrationTrace) -> Dict:
    return trace.to_wire()


def trace_from_wire(d: Dict) -> OrchestrationTrace:
    return OrchestrationTrace.from_wire(d)


def snapshot_to_wire(snap: RuntimeSnapshot) -> Dict:
    return snap.to_dict()


def snapshot_from_wire(d: Dict) -> RuntimeSnapshot:
    from repro.core.descriptors import known_fields

    return RuntimeSnapshot(**known_fields(RuntimeSnapshot, d))


def rejection_to_error(result: InvocationResult,
                       trace: Optional[OrchestrationTrace] = None
                       ) -> WireError:
    """Build the structured wire error for a non-completed result: taxonomy
    code + prose reason + the full trace (and any twin invalidation detail)
    so remote clients lose nothing the in-process caller would see."""
    reason = (result.telemetry or {}).get("reason", f"status {result.status}")
    code_s = result.error_code or classify_rejection(reason).value
    detail: Dict[str, Any] = {"status": result.status,
                              "task_id": result.task_id}
    if trace is not None:
        detail["trace"] = trace_to_wire(trace)
    if "twin invalidated: " in reason:
        # surface the recorded invalidation cause as its own field so
        # clients need not parse prose (PR 3's invalidation_reason)
        detail["invalidation_reason"] = (
            reason.split("twin invalidated: ", 1)[1].split(";")[0])
    return WireError(ErrorCode(code_s), reason, detail)


# ---------------------------------------------------------------------------
# JSON helpers — adapters return numpy arrays/scalars in outputs; the wire
# must not care


def _json_default(o):
    if isinstance(o, np.ndarray):
        return o.tolist()
    if isinstance(o, (np.floating, np.integer, np.bool_)):
        return o.item()
    if isinstance(o, (set, frozenset, tuple)):
        return list(o)
    # NO str() fallback: silently stringifying an unknown object (a bytes
    # payload, a custom class) would make the remote plane execute on
    # corrupted input; refusing loudly keeps to_wire faithful
    raise TypeError(f"{type(o).__name__} is not wire-serializable")


def dumps(obj: Dict) -> bytes:
    try:
        return json.dumps(obj, default=_json_default).encode()
    except (TypeError, ValueError) as e:
        raise ProtocolError(f"value not wire-serializable: {e}") from e


def loads(data: bytes) -> Dict:
    try:
        return json.loads(data or b"{}")
    except json.JSONDecodeError as e:
        raise ProtocolError(f"invalid JSON: {e}") from e


# ---------------------------------------------------------------------------
# binary envelope codec (protocol 1.2): one length-prefixed frame per
# envelope.  Purpose-built for the control path: dict keys from the fixed
# field-tag table encode as 1-2 bytes instead of quoted strings, floats
# travel as raw IEEE doubles instead of repr() text, and float vectors
# (tensor payloads) ride as packed f64 arrays — no base64, no JSON
# re-encode.  Decoding a frame yields EXACTLY what json.loads would have
# yielded for the equivalent JSON body (property-tested), so every endpoint
# is codec-agnostic: negotiation happens at the HTTP layer via
# ``Content-Type`` (request body codec) and ``Accept`` (response codec).


#: content type announcing/requesting the binary codec; anything else —
#: including absence — means JSON, so 1.1 peers keep working unchanged
BINARY_CONTENT_TYPE = "application/x-physmcp"
JSON_CONTENT_TYPE = "application/json"

_MAGIC = 0xA7          # first frame byte: never valid leading JSON
_CODEC_VERSION = 1

# value tags
_T_NONE, _T_TRUE, _T_FALSE = 0x00, 0x01, 0x02
_T_INT, _T_FLOAT = 0x03, 0x04
_T_STR, _T_BYTES = 0x05, 0x06
_T_LIST, _T_DICT = 0x07, 0x08
_T_F64S = 0x09         # packed float64 array (pure-float lists)
_T_IKEY = 0x0A         # interned string (field-tag table index)

#: the field-tag intern table: common envelope/task/result/trace/snapshot
#: keys encode as a varint index instead of a length-prefixed string.
#: APPEND-ONLY — reordering or removing entries is a MAJOR protocol break
#: (both ends index into this table by position).
INTERNED_FIELDS = (
    "protocol_version", "kind", "ok", "body", "error", "plane_id",
    "code", "message", "detail", "task", "tasks", "deadline_s",
    "task_id", "function", "input_modality", "output_modality", "payload",
    "required_telemetry", "latency_budget_ms", "tenant", "priority",
    "backend_preference", "allow_fallback", "twin_mode",
    "twin_min_confidence", "supervision_available", "hop_budget",
    "deadline_budget_ms", "route", "metadata",
    "result", "trace", "status", "resource_id", "session_id", "output",
    "telemetry", "artifacts", "timing_ms", "backend_ms", "total_ms",
    "queue_wait_ms", "error_code", "served_by", "twin_confidence",
    "selected", "attempts", "control_overhead_ms", "matched", "rejected",
    "ticket", "tickets", "entries", "outcomes", "state", "wait_s",
    "events", "next_cursor", "dropped", "dropped_events", "seq",
    "timestamp", "severity", "fields", "health_status", "drift_score",
    "queue_depth", "readiness", "extra", "execution_ms", "observation_ms",
    "descriptors", "descriptor", "snapshot", "twin", "retry_after_s",
    # 1.2 additions (appended — see the append-only rule above; planelint's
    # codec-drift checker pins this against analysis/codec_fields.golden):
    # the remaining wire-dataclass fields and envelope keys that previously
    # rode as raw strings
    "age_of_information_ms", "contamination", "contracts", "fallback_used",
    "invalidation_reason", "last_updated", "max_twin_age_ms", "reason",
    "rejected_reason", "repeated", "shadow_divergence", "viability",
    # 1.3 additions: paged-KV serving capacity telemetry + structured
    # QUEUE_SATURATED refusal detail
    "page_size", "pool_pages", "pool_pages_used", "pool_pages_free",
    "pool_utilization", "prefix_hit_rate", "prefix_cached_tokens",
    "backlog_prefill_tokens", "needed_pages", "reserved_pages",
)
_INTERN_IDS = {s: i for i, s in enumerate(INTERNED_FIELDS)}


def _uvarint(out: bytearray, n: int) -> None:
    while True:
        b = n & 0x7F
        n >>= 7
        if n:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def _json_key(k) -> str:
    """Binary dicts mirror json.dumps key coercion so both codecs decode
    to identical objects (JSON object keys are always strings)."""
    if isinstance(k, str):
        return k
    if k is True:
        return "true"
    if k is False:
        return "false"
    if k is None:
        return "null"
    if isinstance(k, (int, float)):
        return json.dumps(k)
    raise TypeError(f"{type(k).__name__} is not a wire-serializable key")


def _enc(out: bytearray, o) -> None:
    if o is None:
        out.append(_T_NONE)
    elif o is True:
        out.append(_T_TRUE)
    elif o is False:
        out.append(_T_FALSE)
    elif isinstance(o, int) and not isinstance(o, bool):
        out.append(_T_INT)
        # zigzag, arbitrary precision: small magnitudes stay small
        _uvarint(out, o << 1 if o >= 0 else ((-o) << 1) - 1)
    elif isinstance(o, float):
        out.append(_T_FLOAT)
        out += _pack_d(o)
    elif isinstance(o, str):
        idx = _INTERN_IDS.get(o)
        if idx is not None:
            out.append(_T_IKEY)
            _uvarint(out, idx)
        else:
            raw = o.encode("utf-8")
            out.append(_T_STR)
            _uvarint(out, len(raw))
            out += raw
    elif isinstance(o, (bytes, bytearray, memoryview)):
        raw = bytes(o)
        out.append(_T_BYTES)
        _uvarint(out, len(raw))
        out += raw
    elif isinstance(o, dict):
        out.append(_T_DICT)
        _uvarint(out, len(o))
        for k, v in o.items():
            _enc(out, _json_key(k))
            _enc(out, v)
    elif isinstance(o, np.ndarray):
        if o.ndim == 1 and np.issubdtype(o.dtype, np.floating):
            out.append(_T_F64S)
            _uvarint(out, o.shape[0])
            out += o.astype("<f8", copy=False).tobytes()
        else:
            _enc(out, o.tolist())
    elif isinstance(o, (np.floating, np.integer, np.bool_)):
        _enc(out, o.item())
    elif isinstance(o, (list, tuple, set, frozenset)):
        items = list(o)
        if items and all(type(x) is float for x in items):
            # the tensor fast path: payload vectors as raw packed doubles
            out.append(_T_F64S)
            _uvarint(out, len(items))
            out += _pack_ds(items)
        else:
            out.append(_T_LIST)
            _uvarint(out, len(items))
            for x in items:
                _enc(out, x)
    else:
        # same refusal as the JSON encoder: silent stringification would
        # make the remote plane execute on corrupted input
        raise TypeError(f"{type(o).__name__} is not wire-serializable")


_pack_d = _struct.Struct("<d").pack


def _pack_ds(xs) -> bytes:
    return _struct.pack(f"<{len(xs)}d", *xs)


class _Reader:
    __slots__ = ("data", "pos", "end")

    def __init__(self, data: bytes, pos: int, end: int):
        self.data, self.pos, self.end = data, pos, end

    def take(self, n: int) -> bytes:
        if self.pos + n > self.end:
            raise ProtocolError("binary frame truncated")
        out = self.data[self.pos:self.pos + n]
        self.pos += n
        return out

    def uvarint(self) -> int:
        shift, n = 0, 0
        while True:
            if self.pos >= self.end:
                raise ProtocolError("binary frame truncated in varint")
            if shift > 70:
                raise ProtocolError("binary varint overflow")
            b = self.data[self.pos]
            self.pos += 1
            n |= (b & 0x7F) << shift
            if not b & 0x80:
                return n
            shift += 7


def _dec(r: _Reader):
    tag = r.take(1)[0]
    if tag == _T_NONE:
        return None
    if tag == _T_TRUE:
        return True
    if tag == _T_FALSE:
        return False
    if tag == _T_INT:
        z = r.uvarint()
        return (z >> 1) ^ -(z & 1)
    if tag == _T_FLOAT:
        return _struct.unpack("<d", r.take(8))[0]
    if tag == _T_STR:
        try:
            return r.take(r.uvarint()).decode("utf-8")
        except UnicodeDecodeError as e:
            raise ProtocolError(f"binary frame has invalid utf-8: {e}")
    if tag == _T_BYTES:
        return r.take(r.uvarint())
    if tag == _T_LIST:
        return [_dec(r) for _ in range(r.uvarint())]
    if tag == _T_DICT:
        out = {}
        for _ in range(r.uvarint()):
            k = _dec(r)
            if not isinstance(k, str):
                raise ProtocolError("binary dict key must be a string")
            out[k] = _dec(r)
        return out
    if tag == _T_F64S:
        n = r.uvarint()
        return list(_struct.unpack(f"<{n}d", r.take(8 * n)))
    if tag == _T_IKEY:
        idx = r.uvarint()
        if idx >= len(INTERNED_FIELDS):
            raise ProtocolError(f"unknown interned field tag {idx} "
                                "(speaking a newer minor?)")
        return INTERNED_FIELDS[idx]
    raise ProtocolError(f"unknown binary tag 0x{tag:02x}")


def dumps_binary(obj: Dict) -> bytes:
    """One binary envelope frame: magic + codec version + varint length +
    tagged value tree."""
    body = bytearray()
    try:
        _enc(body, obj)
    except (TypeError, ValueError) as e:
        raise ProtocolError(f"value not wire-serializable: {e}") from e
    frame = bytearray((_MAGIC, _CODEC_VERSION))
    _uvarint(frame, len(body))
    frame += body
    return bytes(frame)


def loads_binary(data: bytes) -> Dict:
    data = bytes(data or b"")
    if len(data) < 3 or data[0] != _MAGIC:
        raise ProtocolError("not a binary envelope frame (bad magic)")
    if data[1] != _CODEC_VERSION:
        raise ProtocolError(f"unsupported binary codec version {data[1]}")
    r = _Reader(data, 2, len(data))
    length = r.uvarint()
    if r.pos + length != len(data):
        raise ProtocolError(
            f"binary frame length mismatch (prefix says {length}, "
            f"got {len(data) - r.pos})")
    r.end = r.pos + length
    obj = _dec(r)
    if r.pos != r.end:
        raise ProtocolError("binary frame has trailing bytes")
    return obj


def is_binary(data: bytes) -> bool:
    """Sniff a request/response body: binary frames always lead with the
    magic byte, which can never start JSON."""
    return bool(data) and data[0] == _MAGIC


def wants_binary(header_value: Optional[str]) -> bool:
    """Content negotiation: does a ``Content-Type``/``Accept`` header value
    ask for the binary codec?"""
    return bool(header_value) and BINARY_CONTENT_TYPE in header_value


def encode_envelope(envelope: Dict, binary: bool) -> Tuple[bytes, str]:
    """Encode one envelope for the negotiated codec → (body, content-type)."""
    if binary:
        return dumps_binary(envelope), BINARY_CONTENT_TYPE
    return dumps(envelope), JSON_CONTENT_TYPE


def decode_envelope(data: bytes, content_type: Optional[str] = None) -> Dict:
    """Decode a request/response body by declared content type, falling
    back to frame sniffing (a misdeclared frame should fail loudly in the
    codec, not silently mis-parse)."""
    if wants_binary(content_type) or is_binary(data):
        return loads_binary(data)
    return loads(data)


#: HTTP status per taxonomy code (the envelope's error.code stays the
#: source of truth; the status is a transport courtesy)
HTTP_STATUS: Dict[ErrorCode, int] = {
    ErrorCode.NO_MATCH: 409,
    ErrorCode.POLICY_DENIED: 403,
    ErrorCode.BREAKER_OPEN: 503,
    ErrorCode.QUEUE_SATURATED: 503,
    ErrorCode.DEADLINE: 504,
    ErrorCode.TWIN_INVALID: 409,
    ErrorCode.FALLBACK_EXHAUSTED: 502,
    ErrorCode.NOT_FOUND: 404,
    ErrorCode.BAD_REQUEST: 400,
    ErrorCode.PLANE_UNAVAILABLE: 503,
    ErrorCode.FEDERATION_CYCLE: 409,
    ErrorCode.UNAUTHORIZED: 401,
    ErrorCode.INTERNAL: 500,
}


def http_status(code: ErrorCode) -> int:
    return HTTP_STATUS.get(code, 500)


def split_path(path: str) -> Tuple[str, ...]:
    """``/v1/describe/mem-a?x=1`` → ("v1", "describe", "mem-a")."""
    return tuple(p for p in path.split("?")[0].split("/") if p)
