"""phys-MCP wire protocol v1: versioned envelopes + faithful wire types.

This module is the contract between a control plane and anything that talks
to it across a process boundary: the :class:`~repro.gateway.server.
ControlPlaneGateway` HTTP server, the :class:`~repro.gateway.client.
ControlPlaneClient` SDK, and the federation adapter
(:class:`~repro.substrates.remote_plane.RemotePlaneAdapter`).

Design rules:

- **Versioned** — every envelope carries ``protocol_version``; a plane
  refuses versions it does not speak with ``BAD_REQUEST`` instead of
  mis-parsing them.  Policy: additive body fields are a MINOR bump (old
  clients ignore them), removed/renamed fields or changed semantics are a
  MAJOR bump (the server refuses mismatched majors).
- **Faithful** — ``to_wire``/``from_wire`` round-trip exactly:
  ``TaskRequest`` keeps its payload and task id, descriptors rebuild all
  five nested specs, results/traces/snapshots survive the hop unchanged.
  The redacting forms (``TaskRequest.summary``) never cross the wire.
- **Structured errors** — failures travel as
  :class:`~repro.core.errors.WireError` (code from the closed
  :class:`~repro.core.errors.ErrorCode` taxonomy + prose + detail), never
  as bare strings, so a client can program against outcomes.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Optional, Tuple

import numpy as np

# re-exported: the taxonomy lives in repro.core so the in-process control
# plane can consume it without importing the gateway layer
from repro.core.errors import (ControlPlaneError, ErrorCode,  # noqa: F401
                               WireError, classify_rejection)
from repro.core.descriptors import ResourceDescriptor
from repro.core.invocation import InvocationResult
from repro.core.orchestrator import OrchestrationTrace
from repro.core.tasks import TaskRequest
from repro.core.telemetry import RuntimeSnapshot

#: current protocol version (MAJOR.MINOR); see module docstring for policy.
#: 1.1 (MINOR, additive): ``plane_id`` on envelopes, multi-hop task budget
#: fields (``hop_budget``/``deadline_budget_ms``/``route``), the
#: ``/v1/stream`` + ``/v1/topology`` endpoints, ``retry_after_s`` backoff
#: hints on QUEUE_SATURATED errors, and per-event ``severity`` — 1.0 peers
#: ignore all of it and keep working.
PROTOCOL_VERSION = "1.1"
#: majors this implementation can parse
SUPPORTED_MAJORS = ("1",)


class ProtocolError(ControlPlaneError):
    """Malformed envelope / unsupported version (maps to BAD_REQUEST)."""

    def __init__(self, message: str, detail: Optional[Dict] = None):
        super().__init__(ErrorCode.BAD_REQUEST, message, detail)


def check_version(version: Optional[str]) -> None:
    if not version or version.split(".")[0] not in SUPPORTED_MAJORS:
        raise ProtocolError(
            f"unsupported protocol version {version!r} "
            f"(speaking {PROTOCOL_VERSION})",
            {"protocol_version": PROTOCOL_VERSION})


# ---------------------------------------------------------------------------
# envelopes


def request_envelope(kind: str, body: Dict,
                     plane_id: Optional[str] = None) -> Dict:
    env = {"protocol_version": PROTOCOL_VERSION, "kind": kind, "body": body}
    if plane_id is not None:
        env["plane_id"] = plane_id
    return env


def ok_envelope(kind: str, body: Dict,
                plane_id: Optional[str] = None) -> Dict:
    env = {"protocol_version": PROTOCOL_VERSION, "kind": kind, "ok": True,
           "body": body}
    if plane_id is not None:
        env["plane_id"] = plane_id
    return env


def error_envelope(kind: str, error: WireError,
                   plane_id: Optional[str] = None) -> Dict:
    env = {"protocol_version": PROTOCOL_VERSION, "kind": kind, "ok": False,
           "error": error.to_wire()}
    if plane_id is not None:
        env["plane_id"] = plane_id
    return env


def parse_request(envelope: Dict, expect_kind: Optional[str] = None) -> Dict:
    """Validate an incoming request envelope; returns its body."""
    if not isinstance(envelope, dict):
        raise ProtocolError("request envelope must be a JSON object")
    check_version(envelope.get("protocol_version"))
    if expect_kind is not None and envelope.get("kind") != expect_kind:
        raise ProtocolError(
            f"expected kind {expect_kind!r}, got {envelope.get('kind')!r}")
    body = envelope.get("body")
    if not isinstance(body, dict):
        raise ProtocolError("request envelope has no body object")
    return body


def parse_response(envelope: Dict) -> Dict:
    """Validate a response envelope; returns the body or raises the
    transported :class:`ControlPlaneError`."""
    if not isinstance(envelope, dict):
        raise ProtocolError("response envelope must be a JSON object")
    check_version(envelope.get("protocol_version"))
    if not envelope.get("ok", False):
        err = WireError.from_wire(envelope.get("error") or {})
        raise ControlPlaneError.from_wire_error(err)
    return envelope.get("body") or {}


# ---------------------------------------------------------------------------
# wire converters (thin, named indirection so protocol evolution has one
# place to live; the faithful implementations sit on the types themselves)


def task_to_wire(task: TaskRequest) -> Dict:
    return task.to_wire()


def task_from_wire(d: Dict) -> TaskRequest:
    return TaskRequest.from_wire(d)


def descriptor_to_wire(desc: ResourceDescriptor) -> Dict:
    return desc.to_dict()


def descriptor_from_wire(d: Dict) -> ResourceDescriptor:
    return ResourceDescriptor.from_dict(d)


def result_to_wire(result: InvocationResult) -> Dict:
    return result.to_wire()


def result_from_wire(d: Dict) -> InvocationResult:
    return InvocationResult.from_wire(d)


def trace_to_wire(trace: OrchestrationTrace) -> Dict:
    return trace.to_wire()


def trace_from_wire(d: Dict) -> OrchestrationTrace:
    return OrchestrationTrace.from_wire(d)


def snapshot_to_wire(snap: RuntimeSnapshot) -> Dict:
    return snap.to_dict()


def snapshot_from_wire(d: Dict) -> RuntimeSnapshot:
    from repro.core.descriptors import known_fields

    return RuntimeSnapshot(**known_fields(RuntimeSnapshot, d))


def rejection_to_error(result: InvocationResult,
                       trace: Optional[OrchestrationTrace] = None
                       ) -> WireError:
    """Build the structured wire error for a non-completed result: taxonomy
    code + prose reason + the full trace (and any twin invalidation detail)
    so remote clients lose nothing the in-process caller would see."""
    reason = (result.telemetry or {}).get("reason", f"status {result.status}")
    code_s = result.error_code or classify_rejection(reason).value
    detail: Dict[str, Any] = {"status": result.status,
                              "task_id": result.task_id}
    if trace is not None:
        detail["trace"] = trace_to_wire(trace)
    if "twin invalidated: " in reason:
        # surface the recorded invalidation cause as its own field so
        # clients need not parse prose (PR 3's invalidation_reason)
        detail["invalidation_reason"] = (
            reason.split("twin invalidated: ", 1)[1].split(";")[0])
    return WireError(ErrorCode(code_s), reason, detail)


# ---------------------------------------------------------------------------
# JSON helpers — adapters return numpy arrays/scalars in outputs; the wire
# must not care


def _json_default(o):
    if isinstance(o, np.ndarray):
        return o.tolist()
    if isinstance(o, (np.floating, np.integer, np.bool_)):
        return o.item()
    if isinstance(o, (set, frozenset, tuple)):
        return list(o)
    # NO str() fallback: silently stringifying an unknown object (a bytes
    # payload, a custom class) would make the remote plane execute on
    # corrupted input; refusing loudly keeps to_wire faithful
    raise TypeError(f"{type(o).__name__} is not wire-serializable")


def dumps(obj: Dict) -> bytes:
    try:
        return json.dumps(obj, default=_json_default).encode()
    except (TypeError, ValueError) as e:
        raise ProtocolError(f"value not wire-serializable: {e}") from e


def loads(data: bytes) -> Dict:
    try:
        return json.loads(data or b"{}")
    except json.JSONDecodeError as e:
        raise ProtocolError(f"invalid JSON: {e}") from e


#: HTTP status per taxonomy code (the envelope's error.code stays the
#: source of truth; the status is a transport courtesy)
HTTP_STATUS: Dict[ErrorCode, int] = {
    ErrorCode.NO_MATCH: 409,
    ErrorCode.POLICY_DENIED: 403,
    ErrorCode.BREAKER_OPEN: 503,
    ErrorCode.QUEUE_SATURATED: 503,
    ErrorCode.DEADLINE: 504,
    ErrorCode.TWIN_INVALID: 409,
    ErrorCode.FALLBACK_EXHAUSTED: 502,
    ErrorCode.NOT_FOUND: 404,
    ErrorCode.BAD_REQUEST: 400,
    ErrorCode.PLANE_UNAVAILABLE: 503,
    ErrorCode.FEDERATION_CYCLE: 409,
    ErrorCode.UNAUTHORIZED: 401,
    ErrorCode.INTERNAL: 500,
}


def http_status(code: ErrorCode) -> int:
    return HTTP_STATUS.get(code, 500)


def split_path(path: str) -> Tuple[str, ...]:
    """``/v1/describe/mem-a?x=1`` → ("v1", "describe", "mem-a")."""
    return tuple(p for p in path.split("?")[0].split("/") if p)
