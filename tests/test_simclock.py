"""VirtualClock semantics: the determinism substrate of the scenario
simulator.

- virtual time only moves on advance/sleep, from a fixed epoch, with
  ``now``/``monotonic`` in lockstep;
- bounded waits absorb their timeout into virtual time (discrete-event
  step); unbounded waits are notification-driven and consume no time;
- ``forbid_real_sleep`` catches (or counts) any real sleep on the
  simulated path.
"""
import threading
import time

import pytest

from repro.core.simclock import (SYSTEM_CLOCK, RealSleepForbidden,
                                 SystemClock, VirtualClock,
                                 forbid_real_sleep)

pytestmark = pytest.mark.sim


def test_virtual_time_only_moves_on_advance():
    vc = VirtualClock()
    assert vc.monotonic() == 0.0
    assert vc.now() == VirtualClock.EPOCH
    vc.advance(2.5)
    assert vc.monotonic() == 2.5
    assert vc.now() == VirtualClock.EPOCH + 2.5
    # re-reading does not move time
    assert vc.monotonic() == 2.5


def test_sleep_advances_and_counts():
    vc = VirtualClock()
    vc.sleep(1.0)
    vc.sleep(0.25)
    vc.sleep(0.0)                        # zero sleeps are free
    assert vc.monotonic() == 1.25
    assert vc.virtual_sleeps == 2


def test_advance_to_refuses_backwards():
    vc = VirtualClock()
    vc.advance_to(5.0)
    with pytest.raises(ValueError):
        vc.advance_to(4.0)
    with pytest.raises(ValueError):
        vc.advance(-1.0)


def test_bounded_wait_absorbs_timeout_into_virtual_time():
    vc = VirtualClock()
    cond = threading.Condition()
    with cond:
        hit = vc.wait_for(cond, lambda: False, timeout=3.0)
    assert hit is False
    assert vc.monotonic() == 3.0          # the wait became a time step
    ev = threading.Event()
    assert vc.wait_event(ev, timeout=2.0) is False
    assert vc.monotonic() == 5.0


def test_unbounded_wait_is_notification_driven_and_timeless():
    vc = VirtualClock()
    cond = threading.Condition()
    state = {"ready": False}

    def waker():
        time.sleep(0.01)
        with cond:
            state["ready"] = True
            cond.notify_all()

    t = threading.Thread(target=waker)
    t.start()
    with cond:
        assert vc.wait_for(cond, lambda: state["ready"]) is True
    t.join()
    assert vc.monotonic() == 0.0          # no virtual time passed
    assert vc.virtual_sleeps == 0


def test_forbid_real_sleep_strict_raises():
    with forbid_real_sleep(strict=True) as counter:
        with pytest.raises(RealSleepForbidden):
            time.sleep(0.001)
    assert counter["calls"] == 1
    # the patch is removed on exit
    time.sleep(0)


def test_forbid_real_sleep_counting_mode():
    with forbid_real_sleep(strict=False) as counter:
        time.sleep(0)
        time.sleep(0)
    assert counter["calls"] == 2


def test_system_clock_delegates():
    sc = SystemClock()
    assert abs(sc.now() - time.time()) < 5.0
    ev = threading.Event()
    ev.set()
    assert sc.wait_event(ev, timeout=0.01) is True
    assert SYSTEM_CLOCK.monotonic() <= time.monotonic()
