"""Serving-path tests: engine correctness, continuous batching, admission.

Covers the serving satellite set: empty/partial batches, mixed prompt
lengths and max_new_tokens, token-metric exactness, batched-vs-single
greedy-decode parity — plus the continuous-batching loop (slot reuse,
per-row timelines) and the LM serving adapter's structured refusals.
"""
import threading

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.errors import AdmissionRefused, ErrorCode
from repro.serving import Request, ServingEngine

ARCH = "internlm2-20b"
MAX_SEQ = 64


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config(ARCH))


@pytest.fixture(scope="module")
def params(cfg):
    from repro.models import model_specs
    from repro.models.common import init_params

    return init_params(model_specs(cfg), seed=1)


def make_engine(cfg, params, batch_size=4, max_seq=MAX_SEQ):
    return ServingEngine(cfg, params=params, batch_size=batch_size,
                         max_seq=max_seq)


def make_prompt(rng, cfg, n):
    return rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)


# -- fixed-batch generate() ---------------------------------------------------

def test_generate_empty_group_returns_empty(cfg, params):
    eng = make_engine(cfg, params)
    assert eng.generate([]) == []
    assert eng.metrics["tokens"] == 0


def test_generate_partial_batch(cfg, params):
    rng = np.random.default_rng(0)
    eng = make_engine(cfg, params, batch_size=4)
    reqs = [Request("a", make_prompt(rng, cfg, 6), max_new_tokens=3)]
    out = eng.generate(reqs)
    assert len(out) == 1 and out[0].done
    assert len(out[0].generated) == 3


def test_generate_mixed_lengths_done_exact(cfg, params):
    rng = np.random.default_rng(1)
    eng = make_engine(cfg, params, batch_size=3)
    reqs = [Request("a", make_prompt(rng, cfg, 5), max_new_tokens=2),
            Request("b", make_prompt(rng, cfg, 8), max_new_tokens=7),
            Request("c", make_prompt(rng, cfg, 6), max_new_tokens=4)]
    out = eng.generate(reqs)
    # done flips at exactly max_new_tokens — never an over-append
    for r in out:
        assert r.done and len(r.generated) == r.max_new_tokens
    # early exit: N tokens need N-1 decode steps (first token from prefill)
    assert eng.metrics["decode_steps"] == max(r.max_new_tokens
                                              for r in reqs) - 1


def test_generate_token_metric_counts_only_live_rows(cfg, params):
    rng = np.random.default_rng(2)
    eng = make_engine(cfg, params, batch_size=3)
    reqs = [Request("a", make_prompt(rng, cfg, 6), max_new_tokens=2),
            Request("b", make_prompt(rng, cfg, 6), max_new_tokens=9)]
    eng.generate(reqs)
    # exactly the tokens delivered — not len(requests) x steps
    assert eng.metrics["tokens"] == sum(r.max_new_tokens for r in reqs)


def test_generate_batched_vs_single_parity(cfg, params):
    """Equal-length prompts batched together decode exactly as alone."""
    rng = np.random.default_rng(3)
    prompts = [make_prompt(rng, cfg, 7) for _ in range(3)]
    eng = make_engine(cfg, params, batch_size=3)
    batched = eng.generate([Request(f"b{i}", p, max_new_tokens=5)
                            for i, p in enumerate(prompts)])
    for i, p in enumerate(prompts):
        solo = make_engine(cfg, params, batch_size=1)
        [ref] = solo.generate([Request("s", p, max_new_tokens=5)])
        assert ref.generated == batched[i].generated


def test_generate_structured_refusals(cfg, params):
    eng = make_engine(cfg, params, batch_size=2, max_seq=32)
    with pytest.raises(AdmissionRefused) as ei:
        eng.generate([Request("long", np.ones(40, np.int32))])
    assert ei.value.code == ErrorCode.BAD_REQUEST
    assert "exceeds max_seq" in str(ei.value)
    with pytest.raises(AdmissionRefused):
        eng.generate([Request("empty", np.zeros(0, np.int32))])
    with pytest.raises(AdmissionRefused) as ei:
        # prompt fits but prompt + max_new overflows the cache
        eng.generate([Request("ovf", np.ones(30, np.int32),
                              max_new_tokens=10)])
    assert "kv cache overflow" in str(ei.value)
    with pytest.raises(AdmissionRefused):
        eng.generate([Request(f"x{i}", np.ones(4, np.int32))
                      for i in range(3)])   # group > batch_size


# -- continuous batching ------------------------------------------------------

def test_continuous_matches_single_runs_mixed_lengths(cfg, params):
    """The tentpole exactness claim: requests of different prompt lengths
    and budgets flowing through the shared decode batch (joining, leaving,
    slot reuse) produce token-for-token the same output as isolated runs."""
    rng = np.random.default_rng(4)
    eng = make_engine(cfg, params, batch_size=2)
    shapes = [(5, 3), (9, 6), (6, 1), (7, 4), (8, 5)]   # > 2x slots: reuse
    reqs = [Request(f"r{i}", make_prompt(rng, cfg, pl), max_new_tokens=mn)
            for i, (pl, mn) in enumerate(shapes)]
    for r in reqs:
        eng.submit(r)
    eng.drain()
    assert all(r.done and len(r.generated) == r.max_new_tokens for r in reqs)
    assert eng.metrics["tokens"] == sum(mn for _, mn in shapes)
    for r in reqs:
        solo = make_engine(cfg, params, batch_size=1)
        [ref] = solo.generate([Request("s", r.prompt,
                                       max_new_tokens=r.max_new_tokens)])
        assert ref.generated == r.generated, r.request_id


def test_continuous_telemetry_stamps(cfg, params):
    rng = np.random.default_rng(5)
    eng = make_engine(cfg, params, batch_size=2)
    r = eng.submit(Request("t", make_prompt(rng, cfg, 6), max_new_tokens=4))
    eng.drain()
    assert r.ttft_ms is not None and r.ttft_ms >= 0.0
    assert r.tokens_per_s is not None and r.tokens_per_s > 0.0
    assert not r.expired


def test_continuous_submit_threadsafe_with_driver(cfg, params):
    rng = np.random.default_rng(6)
    eng = make_engine(cfg, params, batch_size=2)
    stop = threading.Event()
    driver = threading.Thread(target=eng.serve_forever, args=(stop,),
                              daemon=True)
    driver.start()
    done = threading.Event()
    finished = []
    eng.on_complete = lambda r: (finished.append(r),
                                 done.set() if len(finished) == 6 else None)
    reqs = [eng.submit(Request(f"p{i}", make_prompt(rng, cfg, 6),
                               max_new_tokens=3)) for i in range(6)]
    assert done.wait(60.0), "driver thread did not finish the queue"
    stop.set()
    eng.wake()          # the idle park is unbounded, not a poll
    driver.join(timeout=5.0)
    assert not driver.is_alive(), "driver did not observe stop after wake"
    assert all(r.done and len(r.generated) == 3 for r in reqs)


def test_continuous_admission_hook_refuses(cfg, params):
    eng = make_engine(cfg, params)

    def refuse(r, engine):
        raise AdmissionRefused(ErrorCode.DEADLINE,
                               f"{r.request_id}: over deadline budget")

    eng.admission = refuse
    with pytest.raises(AdmissionRefused) as ei:
        eng.submit(Request("no", np.ones(4, np.int32)))
    assert ei.value.code == ErrorCode.DEADLINE
    assert eng.backlog_tokens() == 0        # refusal touches no engine state


@pytest.mark.slow
def test_continuous_parity_ring_buffer_and_recurrent_state():
    """Hard case: per-row timelines over ring-buffered local attention and
    recurrent state (recurrentgemma mixes both)."""
    from repro.models import model_specs
    from repro.models.common import init_params

    cfg = reduced(get_config("recurrentgemma-9b"))
    params = init_params(model_specs(cfg), seed=2)
    rng = np.random.default_rng(7)
    eng = ServingEngine(cfg, params=params, batch_size=2, max_seq=MAX_SEQ)
    shapes = [(6, 4), (9, 7), (5, 3)]
    reqs = [Request(f"r{i}", make_prompt(rng, cfg, pl), max_new_tokens=mn)
            for i, (pl, mn) in enumerate(shapes)]
    for r in reqs:
        eng.submit(r)
    eng.drain()
    for r in reqs:
        solo = ServingEngine(cfg, params=params, batch_size=1,
                             max_seq=MAX_SEQ)
        [ref] = solo.generate([Request("s", r.prompt,
                                       max_new_tokens=r.max_new_tokens)])
        assert ref.generated == r.generated, r.request_id


# -- control-plane adapter ----------------------------------------------------

@pytest.fixture(scope="module")
def serving_orchestrator():
    from repro.core.orchestrator import Orchestrator
    from repro.substrates import LmServingAdapter

    orch = Orchestrator(plane="serving-test")
    adapter = LmServingAdapter(batch_size=2, max_seq=MAX_SEQ)
    orch.register(adapter)
    yield orch, adapter
    adapter.close()


def _task(task_id, prompt_len=6, max_new=4, budget_ms=None):
    from repro.core.tasks import TaskRequest

    return TaskRequest(
        task_id=task_id, function="generate",
        input_modality="tokens", output_modality="tokens",
        payload={"prompt": list(range(1, prompt_len + 1)),
                 "max_new_tokens": max_new},
        latency_budget_ms=budget_ms)


def test_adapter_serves_with_telemetry(serving_orchestrator):
    orch, adapter = serving_orchestrator
    res, trace = orch.execute(_task("ok-1"))
    assert res.status == "completed"
    assert trace.selected == adapter.resource_id
    assert len(res.output["tokens"]) == 4
    for field in ("ttft_ms", "tokens_per_s", "step_ms", "drift_score"):
        assert field in res.telemetry
    assert res.telemetry["deadline_expired"] is False


def test_adapter_refuses_doomed_deadline_as_structured_DEADLINE(
        serving_orchestrator):
    orch, adapter = serving_orchestrator
    res, trace = orch.execute(_task("doom-1", max_new=40, budget_ms=0.2))
    assert res.status == "rejected"
    assert res.error_code == ErrorCode.DEADLINE.value
    assert "deadline budget" in trace.rejected_reason
    # a refusal is admission control, not substrate failure: the breaker
    # must stay closed and the next request must serve normally
    res2, _ = orch.execute(_task("ok-2"))
    assert res2.status == "completed"


def test_adapter_rejects_overlong_prompt_as_BAD_REQUEST(serving_orchestrator):
    orch, _ = serving_orchestrator
    res, _ = orch.execute(_task("long-1", prompt_len=MAX_SEQ + 10))
    assert res.status == "rejected"
    assert res.error_code == ErrorCode.BAD_REQUEST.value


def test_adapter_descriptor_and_twin(serving_orchestrator):
    orch, adapter = serving_orchestrator
    desc = adapter.descriptor()
    assert "generate" in desc.capability.functions
    assert desc.capability.input_signal.modality == "tokens"
    twin = orch.twins.get(adapter.resource_id)
    assert twin is not None and twin.surrogate is not None
    sim = twin.surrogate.simulate(_task("sim-1"))
    assert sim["output"]["predicted"] is True
    assert sim["telemetry"]["step_ms"] > 0.0
