"""Per-architecture smoke tests: reduced same-family config, one train step
+ prefill + decode on CPU, asserting output shapes and finiteness.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, reduced, supports_shape
from repro.models import (build_decode_step, build_prefill_step, count_params,
                          decode_cache, loss_fn, model_specs)
from repro.models.common import init_params
from repro.training.train_step import build_train_step, init_train_state

pytestmark = pytest.mark.slow    # heavy suite: excluded from make test-fast

ARCHS = list_archs()


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(rng.normal(size=(B, cfg.encoder_frames,
                                                   cfg.d_model)),
                                  jnp.dtype(cfg.param_dtype))
    if cfg.family == "vision":
        b["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_image_tokens, cfg.d_model)),
            jnp.dtype(cfg.param_dtype))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = reduced(get_config(arch))
    state = init_train_state(cfg)
    step = jax.jit(build_train_step(cfg))
    state, metrics = step(state, _batch(cfg))
    assert jnp.isfinite(metrics["loss"]), (arch, metrics)
    assert metrics["loss"] > 0
    assert jnp.isfinite(metrics["grad_norm"])
    assert int(state.opt.step) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_prefill_and_decode(arch):
    cfg = reduced(get_config(arch))
    params = init_params(model_specs(cfg))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    cache, logits0 = jax.jit(build_prefill_step(cfg))(params, batch)
    assert logits0.shape == (B, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits0))
    dcache = decode_cache(cfg, B, S + 8)
    step = jax.jit(build_decode_step(cfg))
    cache2, logits = step(params, dcache, batch["tokens"][:, :1],
                          jnp.int32(3))
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The registered FULL config carries the assigned dimensions."""
    cfg = get_config(arch)
    assigned = {
        "rwkv6-7b": (32, 4096, 14336, 65536),
        "moonshot-v1-16b-a3b": (48, 2048, None, 163840),
        "deepseek-v2-236b": (60, 5120, None, 102400),
        "whisper-large-v3": (32, 1280, 5120, 51866),
        "llama-3.2-vision-90b": (100, 8192, 28672, 128256),
        "recurrentgemma-9b": (38, 4096, 12288, 256000),
        "internlm2-20b": (48, 6144, 16384, 92544),
        "command-r-35b": (40, 8192, 22528, 256000),
        "nemotron-4-340b": (96, 18432, 73728, 256000),
        "qwen2.5-32b": (64, 5120, 27648, 152064),
    }[arch]
    L, d, ff, v = assigned
    assert cfg.num_layers == L
    assert cfg.d_model == d
    if ff is not None:
        assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_param_counts_in_expected_range():
    expect = {"rwkv6-7b": (6, 9), "deepseek-v2-236b": (220, 250),
              "nemotron-4-340b": (320, 360), "qwen2.5-32b": (28, 36),
              "command-r-35b": (28, 40), "internlm2-20b": (17, 23)}
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch)) / 1e9
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params_much_smaller():
    ds = get_config("deepseek-v2-236b")
    assert count_params(ds, active_only=True) < 0.15 * count_params(ds)


def test_long_context_admission():
    """long_500k runs only for sub-quadratic archs (capability check)."""
    runs = {a for a in ARCHS
            if supports_shape(get_config(a), SHAPES["long_500k"])[0]}
    assert runs == {"rwkv6-7b", "recurrentgemma-9b"}
