"""RQ2: full matcher vs simplified selectors on the curated 7-task suite.

The decisive cases are the ones that need *runtime* semantics (paper §VIII-B):
drifted local backend, stale twin, missing supervision — a flat
discovery-only interface cannot get these right.
"""
import pytest

from repro.core import TaskRequest
from repro.core.matcher import (LatencyOnlySelector, Matcher,
                                ModalityOnlySelector,
                                RandomAdmissibleSelector)
from repro.core.telemetry import RuntimeSnapshot


def seven_task_suite():
    """[(task_factory, inject_fn, expected_resource_or_None)]"""

    def no_inject(orch):
        pass

    def drift_local(orch):
        snap = RuntimeSnapshot("memristive-local", drift_score=0.8,
                               health_status="degraded")
        orch.bus.update_snapshot(snap)

    def stale_chem(orch):
        tw = orch.twins.get("chemical-ode")
        tw.last_sync -= 3600.0

    return [
        # 1: plain fast inference → local in-process fast backend
        (lambda: TaskRequest(function="inference", input_modality="vector",
                             output_modality="vector"),
         no_inject, "memristive-local"),
        # 2: drifted local fast → externalized fast backend
        (lambda: TaskRequest(function="inference", input_modality="vector",
                             output_modality="vector"),
         drift_local, "fast-external"),
        # 3: stale chemical twin within freshness bound → no candidate
        (lambda: TaskRequest(function="assay", input_modality="concentration",
                             output_modality="concentration",
                             max_twin_age_ms=60_000.0),
         stale_chem, None),
        # 4: wetware without supervision → no candidate
        (lambda: TaskRequest(function="screening", input_modality="spikes",
                             output_modality="spikes",
                             supervision_available=False),
         no_inject, None),
        # 5: healthy slow assay → chemical backend
        (lambda: TaskRequest(function="assay", input_modality="concentration",
                             output_modality="concentration"),
         no_inject, "chemical-ode"),
        # 6: supervised screening → local synthetic wetware (lower
        #    lifecycle + orchestration cost than the external CL path)
        (lambda: TaskRequest(function="screening", input_modality="spikes",
                             output_modality="spikes"),
         no_inject, "wetware-synthetic"),
        # 7: directed CL request → validated and accepted
        (lambda: TaskRequest(function="screening", input_modality="spikes",
                             output_modality="spikes",
                             backend_preference="cortical-labs-backend"),
         no_inject, "cortical-labs-backend"),
    ]


def run_suite(selector_cls, fast_service, seed=0):
    from repro.core import Orchestrator
    from repro.substrates import standard_testbed

    correct = 0
    details = []
    for task_fn, inject, expected in seven_task_suite():
        orch = Orchestrator()
        standard_testbed(orch, http_service=fast_service)
        kw = {"seed": seed} if selector_cls is RandomAdmissibleSelector else {}
        sel = selector_cls(orch.registry, orch.bus, orch.twins, orch.policy,
                           **kw)
        inject(orch)
        cand = sel.select(task_fn())
        got = cand.resource_id if cand is not None else None
        ok = got == expected
        correct += ok
        details.append((expected, got, ok))
    return correct, details


def test_full_matcher_seven_of_seven(fast_service):
    correct, details = run_suite(Matcher, fast_service)
    assert correct == 7, details


@pytest.mark.parametrize("selector_cls", [RandomAdmissibleSelector,
                                          ModalityOnlySelector,
                                          LatencyOnlySelector])
def test_baselines_strictly_worse(selector_cls, fast_service):
    correct, details = run_suite(selector_cls, fast_service)
    assert correct < 7, (selector_cls.name, details)
    # the runtime-semantics cases (2, 3, 4) are exactly where they fail
    assert correct <= 5


def test_matcher_is_explainable(orchestrator):
    task = TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector")
    ranked = orchestrator.matcher.rank(task)
    top = [c for c in ranked if c.admissible][0]
    assert set(top.terms) == {"C", "T", "L", "D", "O"}


def test_directed_request_skips_ranking(orchestrator):
    task = TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector",
                       backend_preference="fast-external")
    cand = orchestrator.matcher.select(task)
    assert cand.resource_id == "fast-external"


def test_directed_request_still_validates(orchestrator):
    task = TaskRequest(function="assay", input_modality="vector",
                       output_modality="vector",
                       backend_preference="chemical-ode")
    assert orchestrator.matcher.select(task) is None  # modality mismatch
