"""Pallas kernel validation: shape/dtype sweeps against pure-jnp oracles
(interpret=True executes the kernel body on CPU; TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import mha, mha_ref
from repro.kernels.rglru.ops import linear_recurrence, linear_recurrence_ref
from repro.kernels.rwkv6.ops import time_mix_scan, time_mix_ref

pytestmark = pytest.mark.slow    # heavy suite: excluded from make test-fast

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,H,K,hd", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 512, 8, 1, 128),     # MQA
    (2, 192, 6, 3, 32),      # non-pow2 seq (padding path)
    (1, 128, 4, 2, 128),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, K, hd, causal, dtype):
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, K, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, K, hd)), dtype)
    out = mha(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("block_q,block_k", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_shape_invariance(block_q, block_k):
    q = jnp.asarray(RNG.normal(size=(1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 256, 2, 64)), jnp.float32)
    out = mha(q, k, v, causal=True, block_q=block_q, block_k=block_k)
    ref = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (1, 64, 2, 32, 32),
    (2, 128, 4, 64, 32),
    (1, 256, 2, 16, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_kernel_sweep(B, S, H, hd, chunk, dtype):
    r = jnp.asarray(RNG.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, H, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, H, hd)), dtype)
    # log-decay ≤ 0, including strong decay (the overflow-prone regime the
    # pairwise-exponent formulation is exact for)
    lw = -jnp.asarray(RNG.uniform(0.01, 4.0, size=(B, S, H, hd)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(H, hd)), jnp.float32)
    out = time_mix_scan(r, k, v, lw, u, chunk=chunk)
    ref = time_mix_ref(r, k, v, lw, u)
    scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-6
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32)))) / scale
    assert err < (2e-2 if dtype == jnp.bfloat16 else 1e-5), err


@pytest.mark.parametrize("B,S,W,chunk,block_w", [
    (1, 128, 128, 32, 128),
    (2, 256, 256, 64, 128),
    (1, 512, 384, 128, 128),
])
def test_rglru_kernel_sweep(B, S, W, chunk, block_w):
    a = jnp.asarray(RNG.uniform(0.2, 0.999, size=(B, S, W)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(B, S, W)), jnp.float32)
    h = linear_recurrence(a, b, chunk=chunk, block_w=block_w)
    ref = linear_recurrence_ref(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_rwkv6_state_continuity():
    """Chunk boundaries must be invisible: one chunk == many chunks."""
    B, S, H, hd = 1, 128, 2, 32
    r = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    lw = -jnp.asarray(RNG.uniform(0.05, 1.0, size=(B, S, H, hd)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(H, hd)), jnp.float32)
    o32 = time_mix_scan(r, k, v, lw, u, chunk=32)
    o128 = time_mix_scan(r, k, v, lw, u, chunk=128)
    np.testing.assert_allclose(np.asarray(o32), np.asarray(o128),
                               rtol=1e-4, atol=1e-4)
