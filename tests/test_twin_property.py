"""Property-based tests (hypothesis) for the executable twin plane.

Under ARBITRARY interleavings of mark_synced / invalidate / recalibrate /
telemetry events / measured divergences / serve attempts:

1. confidence stays in [0, 1] after every single operation;
2. an ``invalidate`` never RAISES confidence, and pins ``valid()`` False
   until an explicit re-sync (mark_synced / recalibrate) or a measured
   within-tolerance comparison;
3. every ``served_by: twin`` record cites a twin that was VALID at serve
   time (``twin_serves_invalid`` stays 0 and every serve-log entry carries
   ``valid_at_serve=True`` with confidence at/above the applicable floor).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import TaskRequest, TwinExecutor, TwinState, TwinSyncManager
from repro.core.telemetry import TelemetryBus, TelemetryEvent
from repro.core.twin import TwinNotReady, TwinState as _TwinState
from repro.core.twin_executor import TwinUnavailable


class _StubSurrogate:
    kind = "behavioral"
    tolerance = 0.25

    def simulate(self, task):
        return {"output": {"v": 1.0},
                "telemetry": {"observation_ms": 1.0}, "backend_ms": 0.0}

    def observe(self, task, raw):
        pass

    def divergence(self, real_output, twin_output):
        return 0.0


twin_op = st.one_of(
    st.tuples(st.just("mark"), st.floats(0.0, 1.0)),
    st.tuples(st.just("invalidate"),
              st.sampled_from(["postcondition", "speculation mismatch", ""])),
    st.tuples(st.just("recalibrate"), st.none()),
    st.tuples(st.just("result"), st.floats(0.0, 1.0)),
    st.tuples(st.just("driftev"), st.floats(0.0, 1.0)),
    st.tuples(st.just("diverge"), st.floats(0.0, 2.0)),
    st.tuples(st.just("serve"), st.none()),
)


def _apply(twins: TwinSyncManager, executor: TwinExecutor, task: TaskRequest,
           op, arg) -> None:
    if op == "mark":
        twins.mark_synced("r", drift=arg)
    elif op == "invalidate":
        twins.invalidate("r", arg)
    elif op == "recalibrate":
        twins.recalibrate("r")
    elif op == "result":
        twins._on_event(TelemetryEvent("r", "result", {"drift_score": arg}))
    elif op == "driftev":
        twins._on_event(TelemetryEvent("r", "drift", {"drift_score": arg}))
    elif op == "diverge":
        twins.observe_divergence("r", arg, _StubSurrogate.tolerance)
    elif op == "serve":
        try:
            result = executor.serve(task, "r", "fallback")
            assert result.telemetry["served_by"] == "twin"
        except (TwinUnavailable, TwinNotReady):
            pass


@settings(max_examples=120, deadline=None)
@given(ops=st.lists(twin_op, max_size=60),
       start_conf=st.floats(0.0, 1.0))
def test_twin_state_invariants_under_arbitrary_interleavings(ops, start_conf):
    bus = TelemetryBus()
    twins = TwinSyncManager(bus)
    twins.register(TwinState("t", "r", confidence=start_conf,
                             surrogate=_StubSurrogate()))
    executor = TwinExecutor(twins, bus)
    task = TaskRequest(function="f", input_modality="x", output_modality="x")

    for op, arg in ops:
        before = twins.get("r").confidence
        _apply(twins, executor, task, op, arg)
        tw = twins.get("r")
        # (1) confidence bounded after EVERY operation
        assert 0.0 <= tw.confidence <= 1.0
        assert 0.0 <= tw.fidelity_score <= 1.0
        if op == "invalidate":
            # (2) invalidation never raises confidence and pins validity
            assert tw.confidence <= before
            assert tw.confidence == 0.0
            ok, why = tw.valid(None)
            assert not ok and "invalidated" in why

    # (3) serve-validity invariant: every twin-served record cites a twin
    # valid at serve time, with the confidence captured atomically
    audit = executor.audit()
    assert audit["twin_serves_invalid"] == 0
    floor = _TwinState.DEFAULT_MIN_CONFIDENCE
    for entry in executor.serve_log():
        assert entry["valid_at_serve"] is True
        assert entry["confidence_at_serve"] >= floor - 1e-9


@settings(max_examples=60, deadline=None)
@given(ops=st.lists(twin_op, max_size=40),
       min_conf=st.floats(0.0, 1.0))
def test_per_task_floor_respected_at_serve_time(ops, min_conf):
    """Whatever the interleaving, a serve that succeeds under a per-task
    confidence floor saw confidence >= that floor at the atomic check."""
    bus = TelemetryBus()
    twins = TwinSyncManager(bus)
    twins.register(TwinState("t", "r", surrogate=_StubSurrogate()))
    executor = TwinExecutor(twins, bus)
    task = TaskRequest(function="f", input_modality="x", output_modality="x",
                       twin_min_confidence=min_conf)
    for op, arg in ops:
        _apply(twins, executor, task, op, arg)
    for entry in executor.serve_log():
        assert entry["confidence_at_serve"] >= min_conf - 1e-9
        assert entry["valid_at_serve"] is True
