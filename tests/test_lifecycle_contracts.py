"""Lifecycle state machine (R4) + session contracts (§V-B)."""
import pytest

from repro.core import TaskRequest, contracts_from_descriptor
from repro.core.contracts import TelemetryContract, TimingContract
from repro.core.lifecycle import (LifecycleError, LifecycleManager,
                                  LifecycleState)
from repro.substrates import ChemicalAdapter


def test_legal_transition_chain():
    lm = LifecycleManager()
    rid = "r1"
    lm.prepare(rid)
    lm.ready(rid)
    lm.run(rid)
    lm.complete(rid, needs_reset=True)
    assert lm.state(rid) == LifecycleState.NEEDS_RESET
    lm.recover(rid, "flush")
    assert lm.state(rid) == LifecycleState.READY
    assert [t.action for t in lm.history(rid)] == [
        "prepare", "ready", "invoke", "complete", "flush", "flush-done"]


def test_illegal_transition_raises():
    lm = LifecycleManager()
    with pytest.raises(LifecycleError):
        lm.run("r2")                      # cannot run from UNINITIALIZED
    lm.prepare("r2")
    with pytest.raises(LifecycleError):
        lm.transition("r2", LifecycleState.RUNNING)  # PREPARING -> RUNNING


def test_failed_substrate_can_recover_or_retire():
    lm = LifecycleManager()
    lm.prepare("r3")
    lm.fail("r3", "boom")
    assert lm.state("r3") == LifecycleState.FAILED
    lm.recover("r3")
    assert lm.state("r3") == LifecycleState.READY
    lm.transition("r3", LifecycleState.RETIRED, "retire")
    with pytest.raises(LifecycleError):
        lm.prepare("r3")                  # retired is terminal


def test_contracts_derive_from_descriptor_and_task():
    desc = ChemicalAdapter().descriptor()
    task = TaskRequest(function="assay", input_modality="concentration",
                       output_modality="concentration",
                       latency_budget_ms=10_000.0,
                       required_telemetry=("convergence_ms",))
    c = contracts_from_descriptor(desc, task)
    assert c.timing.deadline_ms == 10_000.0
    assert c.timing.min_stabilization_ms == 500.0
    assert c.telemetry.required_fields == ("convergence_ms",)
    assert c.lifecycle.prepare_actions == ("warmup",)


def test_timing_contract_authoritative_bound():
    t = TimingContract(expected_latency_ms=10, observation_window_ms=100,
                       min_stabilization_ms=50)
    assert not t.result_authoritative(10.0)
    assert t.result_authoritative(51.0)


def test_telemetry_contract_validation():
    c = TelemetryContract(required_fields=("a", "b"))
    ok, missing = c.validate({"a": 1, "b": 2, "c": 3})
    assert ok and missing == ()
    ok, missing = c.validate({"a": 1})
    assert not ok and missing == ("b",)
