"""Training substrate: loss goes down, optimizer semantics, checkpoint/
restore determinism, data pipeline resume, fleet fault tolerance."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.training import AdamWConfig, build_train_step, init_train_state
from repro.training.checkpoint import CheckpointManager
from repro.training.data import PrefetchIterator, SyntheticTokenDataset
from repro.training.optimizer import apply_updates, global_norm, init_opt_state
from repro.training.runner import FleetRunner
from repro.substrates.tpu_pod import TpuPodSubstrate

pytestmark = pytest.mark.slow    # heavy suite: excluded from make test-fast


def test_loss_decreases_over_steps():
    cfg = reduced(get_config("internlm2-20b"), vocab_size=64, num_layers=2)
    state = init_train_state(cfg)
    step = jax.jit(build_train_step(cfg, AdamWConfig(lr=3e-3, warmup_steps=5)))
    data = SyntheticTokenDataset(cfg.vocab_size, 32, 8, seed=5)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_microbatched_grads_match_full_batch():
    """Gradient accumulation must be numerically equivalent (fp32 accum)."""
    import dataclasses
    cfg1 = reduced(get_config("qwen2.5-32b"), vocab_size=64, num_layers=2,
                   microbatches=1)
    cfg4 = dataclasses.replace(cfg1, microbatches=4)
    state1 = init_train_state(cfg1, seed=3)
    state4 = init_train_state(cfg4, seed=3)
    data = SyntheticTokenDataset(64, 16, 8, seed=9)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    s1, m1 = jax.jit(build_train_step(cfg1))(state1, batch)
    s4, m4 = jax.jit(build_train_step(cfg4))(state4, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-2
    # updated params agree to accumulation tolerance
    l1 = jax.tree.leaves(s1.params)
    l4 = jax.tree.leaves(s4.params)
    worst = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(l1, l4))
    assert worst < 5e-2, worst


def test_adamw_moves_toward_minimum():
    params = {"w": jnp.asarray([4.0, -3.0])}
    opt = init_opt_state(params, "float32")
    hp = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||²
        params, opt, m = apply_updates(hp, params, grads, opt)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.5


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros((4,))}
    opt = init_opt_state(params, "float32")
    hp = AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0, warmup_steps=1)
    _, _, m = apply_updates(hp, params, {"w": jnp.full((4,), 1e6)}, opt)
    assert float(m["grad_norm"]) > 1e5          # reported pre-clip


def test_moment_dtype_policy():
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    opt = init_opt_state(params, "bfloat16")
    assert opt.mu["w"].dtype == jnp.bfloat16


def test_checkpoint_restore_resumes_identically():
    cfg = reduced(get_config("internlm2-20b"), vocab_size=64, num_layers=2)
    data = SyntheticTokenDataset(cfg.vocab_size, 16, 4, seed=7)
    step = jax.jit(build_train_step(cfg))
    with tempfile.TemporaryDirectory() as td:
        cm = CheckpointManager(td, keep=2)
        state = init_train_state(cfg)
        for i in range(3):
            state, _ = step(state, {k: jnp.asarray(v)
                                    for k, v in data.batch_at(i).items()})
        cm.save(3, state, {"data": data.state_dict()})
        # continue 2 more steps
        ref = state
        for i in range(3, 5):
            ref, mref = step(ref, {k: jnp.asarray(v)
                                   for k, v in data.batch_at(i).items()})
        # restore and replay
        restored, meta = cm.restore(init_train_state(cfg, seed=99))
        assert meta["step"] == 3
        re = restored
        for i in range(3, 5):
            re, mre = step(re, {k: jnp.asarray(v)
                                for k, v in data.batch_at(i).items()})
        assert abs(float(mre["loss"]) - float(mref["loss"])) < 1e-5


def test_checkpoint_retention_and_async():
    with tempfile.TemporaryDirectory() as td:
        cm = CheckpointManager(td, keep=2, async_save=True)
        tree = {"a": np.ones((3,), np.float32)}
        for s in (1, 2, 3, 4):
            cm.save(s, tree)
        cm.wait()
        assert cm.list_steps() == [3, 4]


def test_prefetch_iterator():
    data = SyntheticTokenDataset(97, 8, 2, seed=1)
    it = PrefetchIterator(iter([data.batch_at(i) for i in range(5)]))
    batches = list(it)
    assert len(batches) == 5
    np.testing.assert_array_equal(batches[2]["tokens"],
                                  data.batch_at(2)["tokens"])


def test_fleet_straggler_mitigation_and_checkpoint_fallback():
    with tempfile.TemporaryDirectory() as td:
        fr = FleetRunner()
        a = TpuPodSubstrate("internlm2-20b", recipe="baseline",
                            ckpt_dir=os.path.join(td, "a"), batch=2, seq=16)
        b = TpuPodSubstrate("internlm2-20b", recipe="tp_only",
                            ckpt_dir=os.path.join(td, "b"), batch=2, seq=16)
        fr.add_slice(a)
        fr.add_slice(b)
        rep = fr.train(quanta=2, steps_per_quantum=2)
        assert sum(rep.placements.values()) == 2
        primary = max(rep.placements, key=rep.placements.get)
        # straggler: slow the primary; placement must move away
        fr.slices[primary].inject_straggler(0.6)
        rep2 = fr.train(quanta=2, steps_per_quantum=2)
        others = {k: v for k, v in rep2.placements.items() if k != primary}
        assert sum(others.values()) >= 1, rep2.placements
        # hard failure: primary cannot prepare; fallback completes the work
        fr.slices[primary].inject_fault("prepare_failure")
        rep3 = fr.train(quanta=1, steps_per_quantum=1, preferred=primary)
        assert rep3.placements, rep3.quanta
        assert all(k != primary for k in rep3.placements)


def test_elastic_scaling_with_shared_checkpoint():
    """A slice added mid-run resumes the shared job from the latest
    checkpoint instead of step 0 (elastic scale-out), and the job survives
    losing its original slice entirely (scale-in/failure)."""
    with tempfile.TemporaryDirectory() as td:
        shared = os.path.join(td, "shared")
        fr = FleetRunner()
        a = TpuPodSubstrate("rwkv6-7b", recipe="baseline",
                            ckpt_dir=shared, batch=2, seq=16)
        fr.add_slice(a)
        rep1 = fr.train(quanta=2, steps_per_quantum=2, shared_job=True)
        assert a._step == 4
        # scale out: slice B joins, sharing the checkpoint directory
        b = TpuPodSubstrate("rwkv6-7b", recipe="tp_only",
                            ckpt_dir=shared, batch=2, seq=16)
        fr.add_slice(b)
        # scale in: slice A dies
        a.inject_fault("prepare_failure")
        rep2 = fr.train(quanta=1, steps_per_quantum=1, shared_job=True)
        assert list(rep2.placements) == [b.resource_id], rep2.placements
        # B resumed from the shared step-4 checkpoint, not from scratch
        assert b._step == 5, b._step
