"""Streaming telemetry: subscriptions, filters, loss accounting, auth.

Covers the ``/v1/stream`` endpoint (chunked ndjson server push): filter
correctness under CONCURRENT publishers, zero-loss delivery verified by
sequence numbers, resume-by-cursor, severity filtering, the bounded cursor
log's ``dropped_events`` counter, wire auth (``UNAUTHORIZED`` + tenant
override), and the client's honored ``retry_after_s`` backpressure hints.
"""
import threading
import time

import pytest

from repro.core import ErrorCode, Orchestrator, TaskRequest
from repro.core.errors import WireError
from repro.gateway import (ControlPlaneClient, ControlPlaneGateway,
                           GatewayError, StreamFilter, event_severity)
from repro.substrates import MemristiveAdapter

RIDS = ("xbar-a", "xbar-b", "xbar-c")


@pytest.fixture()
def plane():
    orch = Orchestrator()
    for rid in RIDS:
        orch.register(MemristiveAdapter(rid))
    gw = ControlPlaneGateway(orch, plane="streamy").start()
    try:
        yield orch, gw, ControlPlaneClient(gw.url)
    finally:
        gw.stop()


def _task(rid=None, **kw):
    return TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector", payload=[0.1, 0.2, 0.3, 0.4],
                       backend_preference=rid, **kw)


# ---------------------------------------------------------------------------
# filters


def test_severity_model():
    assert event_severity("lifecycle", {}) == "debug"
    assert event_severity("result", {"status": "completed"}) == "info"
    assert event_severity("result", {"status": "rejected"}) == "warning"
    assert event_severity("breaker", {"to": "open"}) == "error"
    assert event_severity("breaker", {"to": "healthy"}) == "info"
    assert event_severity("health", {"health_status": "failed"}) == "error"
    assert event_severity("health", {"health_status": "healthy"}) == "info"
    assert event_severity("registry", {"action": "register"}) == "info"


def test_stream_filter_parse_and_match():
    filt = StreamFilter.from_query({"resources": "a,b", "kinds": "result",
                                    "min_severity": "warning"})
    assert filt.matches({"resource_id": "a", "kind": "result",
                         "severity": "error"})
    assert not filt.matches({"resource_id": "c", "kind": "result",
                             "severity": "error"})
    assert not filt.matches({"resource_id": "a", "kind": "health",
                             "severity": "error"})
    assert not filt.matches({"resource_id": "a", "kind": "result",
                             "severity": "info"})
    with pytest.raises(ValueError):
        StreamFilter.from_query({"min_severity": "loud"})


def test_bad_min_severity_is_wire_bad_request(plane):
    _, _, client = plane
    with pytest.raises(GatewayError) as ei:
        client.telemetry(cursor=0)  # sanity: endpoint works
        client._call("GET", "/v1/stream?min_severity=loud")
    assert ei.value.code is ErrorCode.BAD_REQUEST


# ---------------------------------------------------------------------------
# subscriptions under concurrent publishers


def test_filtered_stream_under_concurrent_publishers(plane):
    """Three publisher threads hammer three different substrates; a
    subscription filtered to ONE resource must deliver exactly that
    resource's completed results — no foreign events, no losses."""
    _, _, client = plane
    n_each = 8
    stream = client.stream(resources={"xbar-a"}, kinds={"result"},
                           heartbeat_s=0.5)
    publishers = [
        threading.Thread(target=lambda r=rid: [
            ControlPlaneClient(client.url).invoke(_task(r))
            for _ in range(n_each)])
        for rid in RIDS
    ]
    for t in publishers:
        t.start()
    got = list(stream.events(limit=n_each))
    for t in publishers:
        t.join()
    stream.close()
    assert len(got) == n_each
    assert all(e["resource_id"] == "xbar-a" for e in got)
    assert all(e["kind"] == "result" for e in got)
    # seq strictly increases (the stream never re-delivers or reorders)
    seqs = [e["seq"] for e in got]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)


def test_unfiltered_stream_is_gapless_by_seq(plane):
    """With no filter, the delivered seq run must be contiguous — the
    zero-lost-events guarantee the hierarchy benchmark asserts."""
    _, _, client = plane
    stream = client.stream(heartbeat_s=0.5)
    worker = threading.Thread(
        target=lambda: [client.invoke(_task()) for _ in range(5)])
    worker.start()
    got = list(stream.events(limit=10))
    worker.join()
    stream.close()
    # synthetic registry-baseline entries ride seq 0 (state, not history)
    seqs = [e["seq"] for e in got if e["seq"] > 0]
    assert seqs == list(range(seqs[0], seqs[0] + len(seqs)))


def test_stream_resume_by_cursor_no_loss_no_duplicates(plane):
    _, _, client = plane
    client.invoke(_task())
    s1 = client.stream(cursor=0, kinds={"result"}, heartbeat_s=0.5)
    first = next(iter(s1))
    cursor = s1.cursor
    s1.close()
    client.invoke(_task())
    s2 = client.stream(cursor=cursor, kinds={"result"}, heartbeat_s=0.5)
    second = next(iter(s2))
    s2.close()
    assert second["seq"] > first["seq"]
    assert second["seq"] > cursor


def test_stream_hello_carries_plane_identity(plane):
    orch, gw, client = plane
    stream = client.stream(heartbeat_s=0.5, max_s=0.2)
    # drain until orderly end; hello populated plane_id on first line
    for _ in stream:
        pass
    assert stream.plane_id == orch.topology.plane_id == gw.plane_id
    assert stream.orderly_end


def test_min_severity_stream_skips_routine_traffic(plane):
    orch, _, client = plane
    stream = client.stream(min_severity="error", heartbeat_s=0.3,
                           include_control=True)
    client.invoke(_task())                     # routine: info + debug only
    from repro.core import RuntimeSnapshot
    orch.bus.update_snapshot(RuntimeSnapshot("xbar-b",
                                             health_status="failed"))
    got = []
    for obj in stream:
        if obj.get("stream"):                  # heartbeat/hello
            continue
        got.append(obj)
        break
    stream.close()
    assert got and got[0]["resource_id"] == "xbar-b"
    assert got[0]["severity"] == "error"


def test_registry_baseline_on_cursor_zero(plane):
    """A cursor=0 change-feed subscription receives the CURRENT fleet as
    synthetic register events before live updates."""
    orch, _, client = plane
    stream = client.stream(cursor=0, kinds={"registry"}, heartbeat_s=0.5)
    baseline = [e for e in stream.events(limit=len(RIDS))]
    assert {e["resource_id"] for e in baseline} == set(RIDS)
    assert all(e["fields"].get("baseline") for e in baseline)
    orch.unregister("xbar-c")
    live = next(iter(stream))
    stream.close()
    assert live["resource_id"] == "xbar-c"
    assert live["fields"]["action"] == "unregister"
    assert not live["fields"].get("baseline")


# ---------------------------------------------------------------------------
# bounded cursor log


def test_cursor_log_bounded_with_dropped_events_counter():
    orch = Orchestrator()
    orch.register(MemristiveAdapter("tiny"))
    gw = ControlPlaneGateway(orch, plane="tiny", telemetry_capacity=8)
    gw.start()
    client = ControlPlaneClient(gw.url)
    try:
        for _ in range(6):                     # >8 events, nobody reading
            client.invoke(_task("tiny"))
        out = client.telemetry(cursor=0)
        assert len(out["events"]) <= 8
        assert out["dropped_events"] > 0       # lifetime evictions surfaced
        assert out["dropped"] > 0              # this cursor missed some
    finally:
        gw.stop()


# ---------------------------------------------------------------------------
# wire auth


class TenantBound(MemristiveAdapter):
    """Crossbar whose policy only authorizes tenant-a."""

    def descriptor(self):
        import dataclasses

        desc = super().descriptor()
        cap = dataclasses.replace(
            desc.capability,
            policy=dataclasses.replace(desc.capability.policy,
                                       authorized_tenants=("tenant-a",)))
        return dataclasses.replace(desc, capability=cap)


@pytest.fixture()
def keyed_plane():
    orch = Orchestrator()
    orch.register(TenantBound("bound-xbar"))
    gw = ControlPlaneGateway(orch, plane="keyed",
                             api_keys={"key-a": "tenant-a",
                                       "key-b": "tenant-b"}).start()
    try:
        yield orch, gw
    finally:
        gw.stop()


def test_unauthenticated_request_gets_unauthorized(keyed_plane):
    _, gw = keyed_plane
    for client in (ControlPlaneClient(gw.url),
                   ControlPlaneClient(gw.url, api_key="wrong")):
        with pytest.raises(GatewayError) as ei:
            client.discover()
        assert ei.value.code is ErrorCode.UNAUTHORIZED
        with pytest.raises(GatewayError) as ei:
            client.invoke(_task("bound-xbar"))
        assert ei.value.code is ErrorCode.UNAUTHORIZED


def test_authenticated_tenant_overrides_wire_tenant(keyed_plane):
    """The task CLAIMS tenant-a, but the credential maps to tenant-b: the
    gateway must bind the authenticated identity, so policy refuses — the
    wire tenant field is no longer trusted."""
    _, gw = keyed_plane
    spoofer = ControlPlaneClient(gw.url, api_key="key-b")
    with pytest.raises(GatewayError) as ei:
        spoofer.invoke(_task("bound-xbar", tenant="tenant-a",
                             allow_fallback=False))
    assert ei.value.code is ErrorCode.POLICY_DENIED
    # the rightful credential passes, whatever the wire field says
    owner = ControlPlaneClient(gw.url, api_key="key-a")
    res, _ = owner.invoke(_task("bound-xbar", tenant="someone-else"))
    assert res.status == "completed"


def test_streaming_requires_auth_on_keyed_plane(keyed_plane):
    _, gw = keyed_plane
    with pytest.raises(GatewayError) as ei:
        ControlPlaneClient(gw.url).stream()
    assert ei.value.code is ErrorCode.UNAUTHORIZED
    stream = ControlPlaneClient(gw.url, api_key="key-a").stream(
        heartbeat_s=0.3, max_s=0.1)
    for _ in stream:
        pass
    assert stream.orderly_end


# ---------------------------------------------------------------------------
# backpressure: retry_after_s hints, honored


def test_queue_saturated_carries_retry_after_hint(plane):
    """Synthetic saturation: the error detail must carry a positive
    retry_after_s derived from scheduler stats."""
    _, gw, client = plane
    orig = gw.invoke_into
    fired = []

    def saturated_once(handler, body, tenant=None):
        if not fired:
            fired.append(1)
            err = WireError(ErrorCode.QUEUE_SATURATED,
                            "queue saturated (synthetic)",
                            {"retry_after_s": gw.scheduler.retry_after_s()})
            handler._send_error("invoke", err)
            return
        return orig(handler, body, tenant=tenant)

    gw.invoke_into = saturated_once
    try:
        with pytest.raises(GatewayError) as ei:
            client.invoke(_task(), backpressure_retries=0)
        assert ei.value.code is ErrorCode.QUEUE_SATURATED
        assert ei.value.detail["retry_after_s"] > 0
    finally:
        gw.invoke_into = orig


def test_client_honors_retry_after_with_jittered_backoff(plane):
    """First response: QUEUE_SATURATED + hint.  The client must wait ~hint
    (jittered) and retry — the second attempt completes."""
    _, gw, client = plane
    orig = gw.invoke_into
    calls = []

    def saturated_once(handler, body, tenant=None):
        calls.append(time.perf_counter())
        if len(calls) == 1:
            handler._send_error("invoke", WireError(
                ErrorCode.QUEUE_SATURATED, "queue saturated (synthetic)",
                {"retry_after_s": 0.08}))
            return
        return orig(handler, body, tenant=tenant)

    gw.invoke_into = saturated_once
    try:
        res, _ = client.invoke(_task())
        assert res.status == "completed"
        assert len(calls) == 2
        gap = calls[1] - calls[0]
        assert gap >= 0.08 * 0.5                 # jitter floor honored
    finally:
        gw.invoke_into = orig


def test_backoff_never_overruns_the_deadline_budget(plane):
    """A huge hint with a small task budget must raise IMMEDIATELY (honoring
    the hint would blow the deadline), not sleep through it."""
    _, gw, client = plane
    orig = gw.invoke_into

    def always_saturated(handler, body, tenant=None):
        handler._send_error("invoke", WireError(
            ErrorCode.QUEUE_SATURATED, "queue saturated (synthetic)",
            {"retry_after_s": 30.0}))

    gw.invoke_into = always_saturated
    try:
        t0 = time.perf_counter()
        with pytest.raises(GatewayError) as ei:
            client.invoke(_task(latency_budget_ms=200.0))
        assert ei.value.code is ErrorCode.QUEUE_SATURATED
        assert time.perf_counter() - t0 < 5.0
    finally:
        gw.invoke_into = orig
