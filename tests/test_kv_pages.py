"""Property tests for the paged-KV allocator and prefix cache.

The allocator invariants (no leaks, no double frees, refcounts drain to
zero, prefix sharing never aliases divergent suffixes) are checked with
randomized operation sequences validated against a pure-python reference
model.  When ``hypothesis`` is installed the same state machine also runs
under its shrinking engine; the seeded fallback keeps the properties
exercised in environments without it.
"""
import numpy as np
import pytest

from repro.serving.kv_pages import (PagePool, PoolExhausted, PrefixCache,
                                    _block_keys)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# -- PagePool basics ----------------------------------------------------------

def test_alloc_never_returns_null_page():
    pool = PagePool(8, 16)
    pages = pool.alloc(8)
    assert 0 not in pages
    assert sorted(pages) == list(range(1, 9))


def test_alloc_exhaustion_raises_and_leaves_pool_intact():
    pool = PagePool(4, 16)
    pool.alloc(3)
    with pytest.raises(PoolExhausted):
        pool.alloc(2)
    assert pool.free_pages() == 1            # failed alloc took nothing
    pool.alloc(1)
    assert pool.free_pages() == 0


def test_refcount_lifecycle_and_double_free():
    pool = PagePool(4, 16)
    [p] = pool.alloc(1)
    assert pool.refcount(p) == 1
    assert pool.incref(p) == 2
    assert pool.decref(p) == 1
    assert pool.decref(p) == 0               # freed here
    assert pool.free_pages() == 4
    with pytest.raises(AssertionError):
        pool.decref(p)                       # double free


def test_reserve_is_admission_accounting_not_allocation():
    pool = PagePool(8, 16)
    assert pool.reserve(5)
    assert pool.free_pages() == 8            # nothing allocated yet
    assert not pool.reserve(4)               # 5 + 4 > 8
    assert pool.reserve(3)
    pool.unreserve(5)
    assert pool.reserved_pages == 3
    pool.unreserve(3)
    assert pool.reserved_pages == 0


def test_audit_clean_pool():
    pool = PagePool(6, 16)
    a = pool.alloc(2)
    stats = pool.audit()
    assert stats["used"] == 2 and stats["free"] == 4
    for p in a:
        pool.decref(p)
    assert pool.audit()["used"] == 0


# -- randomized allocator state machine --------------------------------------

def _run_pool_ops(seed: int, num_pages: int = 12, steps: int = 400):
    """Random alloc/incref/decref/reserve ops against a reference model."""
    rng = np.random.default_rng(seed)
    pool = PagePool(num_pages, 16)
    model = {}                               # page -> refcount
    reserved = 0
    for _ in range(steps):
        op = rng.integers(0, 5)
        if op == 0:                          # alloc
            n = int(rng.integers(1, 4))
            if pool.free_pages() >= n:
                pages = pool.alloc(n)
                assert len(set(pages)) == n
                assert not (set(pages) & set(model)), "allocated a live page"
                for p in pages:
                    model[p] = 1
            else:
                with pytest.raises(PoolExhausted):
                    pool.alloc(n)
        elif op == 1 and model:              # incref
            p = int(rng.choice(list(model)))
            model[p] += 1
            assert pool.incref(p) == model[p]
        elif op == 2 and model:              # decref
            p = int(rng.choice(list(model)))
            model[p] -= 1
            assert pool.decref(p) == model[p]
            if model[p] == 0:
                del model[p]
        elif op == 3:                        # reserve
            n = int(rng.integers(1, 5))
            ok = pool.reserve(n)
            assert ok == (reserved + n <= num_pages)
            if ok:
                reserved += n
        elif op == 4 and reserved:           # unreserve
            n = int(rng.integers(1, reserved + 1))
            pool.unreserve(n)
            reserved -= n
        stats = pool.audit()                 # invariants hold at every step
        assert stats["used"] == len(model)
        assert stats["free"] == num_pages - len(model)
        assert stats["reserved"] == reserved
        for p, rc in model.items():
            assert pool.refcount(p) == rc
    # drain: refcounts all the way to zero releases every page
    for p, rc in list(model.items()):
        for _ in range(rc):
            pool.decref(p)
    assert pool.audit()["used"] == 0
    assert pool.free_pages() == num_pages


@pytest.mark.parametrize("seed", range(8))
def test_pool_random_ops(seed):
    _run_pool_ops(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_pool_random_ops_hypothesis(seed):
        _run_pool_ops(seed, steps=120)


# -- chain hash ---------------------------------------------------------------

def test_block_keys_chain_depends_on_all_prior_blocks():
    ps = 4
    a = np.arange(12, dtype=np.int32)
    b = a.copy()
    b[1] = 99                                # mutate inside block 0
    ka = _block_keys(a, ps, 3)
    kb = _block_keys(b, ps, 3)
    assert ka[0] != kb[0]
    assert ka[1] != kb[1] and ka[2] != kb[2]   # chained: later keys differ too
    c = a.copy()
    c[5] = 99                                # mutate inside block 1 only
    kc = _block_keys(c, ps, 3)
    assert ka[0] == kc[0]                    # block 0 unaffected
    assert ka[1] != kc[1] and ka[2] != kc[2]


# -- PrefixCache --------------------------------------------------------------

def _mkpool(pages=32, ps=4):
    pool = PagePool(pages, ps)
    return pool, PrefixCache(pool)


def test_prefix_lookup_miss_then_hit():
    pool, pc = _mkpool()
    prompt = np.arange(10, dtype=np.int32)
    assert pc.lookup(prompt, 4) == (0, [])
    pages = pool.alloc(3)
    pc.insert(prompt, pages, 4)              # registers blocks 0 and 1
    n, shared = pc.lookup(prompt, 4)
    assert n == 2 and shared == pages[:2]
    assert pool.refcount(pages[0]) == 3      # owner + cache + lookup
    assert pc.probe(prompt, 4) == 8


def test_prefix_lookup_always_leaves_a_suffix_token():
    """A prompt that is exactly whole cached blocks must still prefill ≥1
    token (the engine needs prefill logits for the first generated token)."""
    pool, pc = _mkpool()
    prompt = np.arange(8, dtype=np.int32)    # exactly 2 blocks of 4
    pages = pool.alloc(2)
    pc.insert(prompt, pages, 4)
    n, shared = pc.lookup(prompt, 4)
    assert n == 1 and shared == pages[:1]    # capped below full coverage
    assert pc.probe(prompt, 4) == 4


def test_prefix_sharing_never_aliases_divergent_suffixes():
    pool, pc = _mkpool()
    common = np.arange(8, dtype=np.int32)
    a = np.concatenate([common, np.array([70, 71, 72], np.int32)])
    b = np.concatenate([common, np.array([80, 81, 82], np.int32)])
    pages_a = pool.alloc(3)
    pc.insert(a, pages_a, 4)
    n, shared = pc.lookup(b, 4)
    assert n == 2 and shared == pages_a[:2]  # common full blocks shared
    # b's divergent block must get its own page, never a's third page
    fresh = pool.alloc(1)
    assert fresh[0] != pages_a[2]
    pc.insert(b, shared + fresh, 4)
    # a's third block key is untouched: looking up a still returns a's page
    n_a, shared_a = pc.lookup(a, 4)
    assert shared_a[:2] == pages_a[:2]
    assert pc.probe(a, 4) == 8               # a's block 2 is a partial (3 tok)


def test_prefix_divergence_inside_a_block_shares_nothing_past_it():
    pool, pc = _mkpool()
    a = np.arange(8, dtype=np.int32)
    b = a.copy()
    b[2] = 99                                # diverges inside block 0
    pages = pool.alloc(2)
    pc.insert(a, pages, 4)
    assert pc.lookup(b, 4) == (0, [])


def test_prefix_eviction_decrefs_and_frees_cache_only_pages():
    pool, pc = _mkpool(pages=4, ps=4)
    prompt = np.arange(8, dtype=np.int32)
    pages = pool.alloc(2)
    pc.insert(prompt, pages, 4)
    for p in pages:                          # owner finishes
        pool.decref(p)
    assert pool.used_pages() == 2            # held by the cache alone
    assert pc.evict_one()
    assert pc.evict_one()
    assert not pc.evict_one()
    assert pool.used_pages() == 0
    assert pool.audit()["used"] == 0


def test_prefix_hit_rate_accounting():
    pool, pc = _mkpool()
    prompt = np.arange(9, dtype=np.int32)
    pages = pool.alloc(3)
    pc.insert(prompt, pages, 4)
    pc.lookup(prompt, 4)                     # 8 of 9 lookup tokens cached
    assert pc.hit_tokens == 8 and pc.lookup_tokens == 9
    assert pc.hit_rate() == pytest.approx(8 / 9)


@pytest.mark.parametrize("seed", range(4))
def test_prefix_cache_random_workload_drains_clean(seed):
    """Random insert/lookup/evict/finish traffic: every page the model
    thinks is live is live, and a full drain releases everything."""
    rng = np.random.default_rng(seed)
    ps = 4
    pool = PagePool(64, ps)
    pc = PrefixCache(pool)
    live = []                                # [(pages, n_shared)]
    for _ in range(60):
        op = rng.integers(0, 3)
        if op == 0:                          # admit a request
            length = int(rng.integers(1, 17))
            prompt = rng.integers(0, 6, size=length).astype(np.int32)
            need = -(-length // ps)
            n, shared = pc.lookup(prompt, ps)
            fresh_n = need - n
            if pool.free_pages() < fresh_n:
                for p in shared:
                    pool.decref(p)
                continue
            pages = list(shared) + pool.alloc(fresh_n)
            pc.insert(prompt, pages, ps)
            live.append(pages)
        elif op == 1 and live:               # finish a request
            pages = live.pop(int(rng.integers(0, len(live))))
            for p in pages:
                pool.decref(p)
        elif op == 2:
            pc.evict_one()
        pool.audit()
    for pages in live:
        for p in pages:
            pool.decref(p)
    pc.flush()
    stats = pool.audit()
    assert stats["used"] == 0 and stats["free"] == 64
