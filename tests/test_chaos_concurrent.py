"""Concurrent chaos: recovery exercised on a live, loaded control plane.

- 8 producer threads × ~100 tasks against ONE shared orchestrator while a
  fault is injected mid-stream: the breaker must quarantine the faulty
  substrate, no session may start on it while quarantined, no semaphore
  (or probe slot) may leak, and every task must still resolve.
- ``run_campaign_concurrent``: the full scenario matrix passes on a shared
  loaded orchestrator, with breaker trajectories asserting quarantine AND
  probation re-admission.
"""
import threading
import time

import pytest

from repro.core import ControlPlaneScheduler, Orchestrator, TaskRequest
from repro.core.faults import (build_concurrent_campaign, inject_drift,
                               inject_invoke_failure, run_campaign_concurrent)
from repro.core.health import BreakerState
from tests.test_scheduler_concurrency import (NORMALIZED_STATUSES,
                                              SyntheticAdapter)

pytestmark = pytest.mark.chaos


def _task(i: int) -> TaskRequest:
    # 4-wide payload: the crossbar/HTTP backends expect a length-4 vector
    return TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector",
                       payload=[0.2, 0.4, 0.1, 0.3])


def test_stress_8_threads_with_midstream_fault_quarantines_and_recovers():
    orch = Orchestrator(health={"cooldown_s": 60.0})   # no auto re-admission
    flaky = SyntheticAdapter("syn-flaky", 4, dwell_s=0.001)
    stable = SyntheticAdapter("syn-stable", 4, dwell_s=0.001)
    orch.register(flaky)           # registered first → preferred while tied
    orch.register(stable)

    fail = {"on": False}
    inner = SyntheticAdapter.invoke

    def flaky_invoke(session):
        if fail["on"]:
            raise RuntimeError("chaos: mid-stream invoke failure")
        return inner(flaky, session)

    flaky.invoke = flaky_invoke

    results = []
    res_lock = threading.Lock()
    with ControlPlaneScheduler(orch, workers=12, queue_size=128) as sched:
        def producer(k):
            futs = []
            for i in range(13):
                if k == 0 and i == 4:
                    fail["on"] = True          # fault lands mid-stream
                futs.append(sched.submit_async(_task(k * 100 + i)))
                time.sleep(0.001)
            got = [f.result(timeout=60) for f in futs]
            with res_lock:
                results.extend(got)

        threads = [threading.Thread(target=producer, args=(k,))
                   for k in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert sched.drain(timeout=60)

        assert orch.health.state("syn-flaky") is BreakerState.OPEN
        # zero sessions on the quarantined substrate: its invocation count
        # must stay frozen across a fresh burst of tasks
        n_frozen = flaky.invocations
        more = sched.submit_many([_task(1000 + i) for i in range(30)])
        assert flaky.invocations == n_frozen
        assert all(r.status == "completed" for r, _ in more)
        assert {r.resource_id for r, _ in more} == {"syn-stable"}

    assert len(results) == 8 * 13
    assert {r.status for r, _ in results} <= NORMALIZED_STATUSES
    # the campaign loses nothing: every task completed (fallback covered
    # the fault window; the breaker only changes WHERE tasks run)
    assert all(r.status == "completed" for r, _ in results), \
        {r.status for r, _ in results}
    sids = [r.session_id for r, _ in results]
    assert len(set(sids)) == len(sids)
    # no semaphore or probe-slot leaks, and the quarantine audit is clean
    assert orch.policy.fully_released(), orch.policy.outstanding()
    assert orch.health.audit()["started_while_open"] == 0
    for a in (flaky, stable):
        assert a.peak_in_flight <= a.max_concurrent
        assert orch.lifecycle.active_sessions(a.resource_id) == 0


def test_concurrent_campaign_matrix_passes_on_shared_loaded_plane(
        fast_service):
    from repro.substrates import standard_testbed

    orch = Orchestrator(health={"cooldown_s": 0.2, "probes_to_close": 2})
    standard_testbed(orch, http_service=fast_service)
    report = run_campaign_concurrent(
        orch, build_concurrent_campaign(), workers=8,
        load_template=_task, load_tasks=48)
    assert report["all_pass"], \
        [r for r in report["rows"] if not r["pass"]]
    # observed-vs-expected table matches scenario by scenario
    for row in report["rows"]:
        assert set(row["observed"]) <= set(row["expected"]), row
        assert row["mismatch_reason"] is None
    # quarantine + re-admission trajectories were really exercised
    readmitted = [r for r in report["rows"] if r["breaker_rid"]]
    assert len(readmitted) == 4
    # zero tasks started on quarantined resources; nothing leaked
    assert report["audit"]["started_while_open"] == 0
    assert report["audit"]["probes_outstanding"] == 0
    assert report["policy_leak_free"]
    assert set(report["load_statuses"]) == {"completed"}


def test_injectors_compose_and_clear():
    orch = Orchestrator(health=False)
    a = SyntheticAdapter("syn-a", 2, dwell_s=0.0)
    orch.register(a)
    from repro.core.faults import compose
    inj = compose(inject_drift("syn-a", 0.9),
                  inject_invoke_failure("syn-a"))
    inj.apply(orch)
    assert orch.bus.snapshot("syn-a").drift_score == 0.9
    with pytest.raises(RuntimeError, match="chaos"):
        a.invoke(None)
    inj.clear(orch)
    assert orch.bus.snapshot("syn-a").drift_score == 0.0
    res, _ = orch.submit(_task(1))
    assert res.status == "completed"
