"""HealthManager unit tests: breaker state machine, quarantine semantics,
probation trickle, recover-on-reopen, and a seeded-random legality sweep
(the hypothesis-widened version lives in test_health_property.py)."""
import random
import time

import pytest

from repro.core import Orchestrator, TaskRequest
from repro.core.health import (BreakerState, HealthManager, HealthThresholds,
                               LEGAL_BREAKER)
from repro.core.policy import PolicyManager
from repro.core.telemetry import RuntimeSnapshot, TelemetryBus, TelemetryEvent
from tests.test_scheduler_concurrency import SyntheticAdapter


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def make_health(**kw):
    bus = TelemetryBus()
    policy = PolicyManager()
    kw.setdefault("cooldown_s", 1.0)
    kw.setdefault("probes_to_close", 2)
    return HealthManager(bus, policy, **kw), bus, policy


def attempt(h, rid, ok):
    allowed, token, why = h.begin_attempt(rid)
    if allowed:
        h.finish_attempt(token, ok=ok, kind="test")
    return allowed, why


def _task(i=0, **kw):
    kw.setdefault("function", "inference")
    kw.setdefault("input_modality", "vector")
    kw.setdefault("output_modality", "vector")
    kw.setdefault("payload", [i])
    return TaskRequest(**kw)


# -- state machine ------------------------------------------------------------

def test_consecutive_failures_trip_open_then_probation_then_healthy():
    clock = FakeClock()
    h, bus, policy = make_health(clock=clock)
    for _ in range(3):
        attempt(h, "r", ok=False)
    assert h.state("r") is BreakerState.OPEN
    # quarantined: attempts are refused outright
    allowed, why = attempt(h, "r", ok=True)
    assert not allowed and "quarantined" in why
    # cooldown not elapsed yet
    clock.t += 0.5
    assert h.state("r") is BreakerState.OPEN
    clock.t += 0.6
    h.tick()
    assert h.state("r") is BreakerState.PROBATION
    attempt(h, "r", ok=True)
    assert h.state("r") is BreakerState.PROBATION    # 1 of 2 probes
    attempt(h, "r", ok=True)
    assert h.state("r") is BreakerState.HEALTHY
    # the rising error rate passes through the degraded warning band first
    assert h.trajectory("r") == ["degraded", "open", "probation", "healthy"]


def test_probe_failure_reopens_with_backoff():
    clock = FakeClock()
    h, bus, policy = make_health(clock=clock, cooldown_s=1.0,
                                 cooldown_backoff=2.0)
    for _ in range(3):
        attempt(h, "r", ok=False)
    clock.t += 1.1
    h.tick()
    assert h.state("r") is BreakerState.PROBATION
    attempt(h, "r", ok=False)
    assert h.state("r") is BreakerState.OPEN
    clock.t += 1.1                       # old cooldown is no longer enough
    h.tick()
    assert h.state("r") is BreakerState.OPEN
    clock.t += 1.0                       # 2.1 total >= backed-off 2.0
    h.tick()
    assert h.state("r") is BreakerState.PROBATION


def test_probation_budget_bounds_concurrent_probes():
    clock = FakeClock()
    h, bus, policy = make_health(clock=clock, probe_budget=1)
    for _ in range(3):
        attempt(h, "r", ok=False)
    clock.t += 1.1
    h.tick()
    allowed1, token1, _ = h.begin_attempt("r")
    assert allowed1 and token1.probe
    # matcher-facing admission reflects the exhausted trickle budget
    ok, why = h.admissible("r")
    assert not ok and "probation" in why
    allowed2, token2, why2 = h.begin_attempt("r")
    assert not allowed2 and "budget" in why2
    h.finish_attempt(token1, ok=True)
    assert policy.probes_held("r") == 0  # probe slot returned
    allowed3, token3, _ = h.begin_attempt("r")
    assert allowed3
    h.finish_attempt(token3, ok=True)
    assert h.state("r") is BreakerState.HEALTHY


def test_drift_snapshot_trips_and_recovers():
    h, bus, policy = make_health()
    bus.update_snapshot(RuntimeSnapshot("r", drift_score=0.35,
                                        health_status="degraded"))
    assert h.state("r") is BreakerState.DEGRADED
    bus.update_snapshot(RuntimeSnapshot("r", drift_score=0.1))
    assert h.state("r") is BreakerState.HEALTHY
    bus.update_snapshot(RuntimeSnapshot("r", drift_score=0.8,
                                        health_status="degraded"))
    assert h.state("r") is BreakerState.OPEN


def test_failed_snapshot_trips_open():
    h, bus, policy = make_health()
    bus.update_snapshot(RuntimeSnapshot("r", health_status="failed"))
    assert h.state("r") is BreakerState.OPEN


def test_error_rate_trips_before_consecutive_threshold():
    h, bus, policy = make_health(
        thresholds={"min_samples": 6, "error_rate_to_open": 0.5,
                    "consecutive_failures_to_open": 100})
    # alternate so consecutive failures stay < 2, but the windowed rate
    # reaches the threshold with enough samples
    for ok in (True, False, True, False, False, True, False, False):
        attempt(h, "r", ok=ok)
        if h.state("r") is BreakerState.OPEN:
            break
    assert h.state("r") is BreakerState.OPEN


def test_breaker_events_published_on_bus():
    h, bus, policy = make_health()
    seen = []
    bus.subscribe(lambda ev: seen.append(ev) if ev.kind == "breaker" else None)
    for _ in range(3):
        attempt(h, "r", ok=False)
    assert any(ev.fields["to"] == "open" for ev in seen)


def test_thresholds_from_descriptor():
    orch = Orchestrator()
    orch.register(SyntheticAdapter("syn-a", 2))
    th = HealthThresholds.from_descriptor(orch.registry.get("syn-a"))
    assert th.expected_latency_ms == 5.0


# -- orchestrator / matcher wiring -------------------------------------------

def test_quarantined_resource_excluded_by_matcher_and_reroutes():
    orch = Orchestrator(health={"cooldown_s": 60.0})
    good = SyntheticAdapter("syn-good", 4, dwell_s=0.0)
    bad = SyntheticAdapter("syn-bad", 4, dwell_s=0.0)
    # identical descriptors rank tied; stable sort prefers the first
    # registered, so register the faulty one first to guarantee attempts
    orch.register(bad)
    orch.register(good)

    def failing_invoke(session):
        raise RuntimeError("boom")

    bad.invoke = failing_invoke
    # drive until the breaker trips; every task still completes (fallback)
    for _ in range(20):
        if orch.health.state("syn-bad") is BreakerState.OPEN:
            break
        res, _ = orch.submit(_task())
        assert res.status == "completed"
    assert orch.health.state("syn-bad") is BreakerState.OPEN
    n_bad = bad.invocations
    for i in range(10):
        res, trace = orch.submit(_task(i))
        assert res.status == "completed"
        assert res.resource_id == "syn-good"
        assert not trace.fallback_used       # excluded at match time
    assert bad.invocations == n_bad          # zero executions while open
    assert orch.health.audit()["started_while_open"] == 0
    assert orch.policy.fully_released()


def test_directed_task_rejected_while_quarantined():
    orch = Orchestrator(health={"cooldown_s": 60.0})
    bad = SyntheticAdapter("syn-bad", 2, dwell_s=0.0)
    orch.register(bad)
    bad.invoke = lambda session: (_ for _ in ()).throw(RuntimeError("boom"))
    for _ in range(4):
        orch.submit(_task())
    assert orch.health.state("syn-bad") is BreakerState.OPEN
    res, trace = orch.submit(_task(backend_preference="syn-bad"))
    assert res.status == "rejected"
    assert "quarantined" in (trace.rejected_reason or "")


def test_readmission_runs_recover_on_reopen():
    """Half-opening re-arms the substrate: adapter reset + fresh snapshot
    before the first probation probe."""
    orch = Orchestrator(health={"cooldown_s": 0.05, "probes_to_close": 1})
    a = SyntheticAdapter("syn-flaky", 2, dwell_s=0.0)
    orch.register(a)
    inner = SyntheticAdapter.invoke
    fail = {"on": True}

    def flaky_invoke(session):
        if fail["on"]:
            raise RuntimeError("boom")
        return inner(a, session)

    a.invoke = flaky_invoke
    for _ in range(3):
        orch.submit(_task())
    assert orch.health.state("syn-flaky") is BreakerState.OPEN
    fail["on"] = False
    resets_before = a.resets
    deadline = time.monotonic() + 10.0
    while (orch.health.state("syn-flaky") is not BreakerState.HEALTHY
           and time.monotonic() < deadline):
        orch.submit(_task())
        time.sleep(0.01)
    assert orch.health.state("syn-flaky") is BreakerState.HEALTHY
    assert a.resets > resets_before          # recover-on-reopen ran
    res, _ = orch.submit(_task(backend_preference="syn-flaky"))
    assert res.status == "completed"


def test_health_disabled_keeps_seed_semantics():
    orch = Orchestrator(health=False)
    assert orch.health is None
    a = SyntheticAdapter("syn-bad", 2, dwell_s=0.0)
    b = SyntheticAdapter("syn-good", 2, dwell_s=0.0)
    orch.register(a)
    orch.register(b)
    a.invoke = lambda session: (_ for _ in ()).throw(RuntimeError("boom"))
    for i in range(8):
        res, _ = orch.submit(_task(i))
        assert res.status == "completed"
    # without breakers the faulty backend keeps being attempted
    assert a.invocations == 0 and b.invocations == 8
    assert orch.policy.fully_released()


# -- seeded-random legality sweep (always runs, no hypothesis needed) --------

def run_breaker_sequence(ops, *, cooldown_s=0.7, probes_to_close=2):
    """Drive one breaker through an arbitrary op sequence on a fake clock;
    returns (manager, history).  Never raises BreakerError by construction
    of the manager — the caller asserts the recorded history is legal."""
    clock = FakeClock()
    h, bus, policy = make_health(clock=clock, cooldown_s=cooldown_s,
                                 probes_to_close=probes_to_close)
    for op in ops:
        kind = op[0]
        if kind == "outcome":
            attempt(h, "r", ok=op[1])
        elif kind == "drift":
            status = ("failed" if op[1] > 0.95 else
                      "degraded" if op[1] > 0.3 else "healthy")
            bus.update_snapshot(RuntimeSnapshot("r", drift_score=op[1],
                                                health_status=status))
        elif kind == "advance":
            clock.t += op[1]
        elif kind == "tick":
            h.tick()
    return h, h.history("r")


def assert_history_legal(history):
    legal = {src.value: tuple(d.value for d in dsts)
             for src, dsts in LEGAL_BREAKER.items()}
    prev = BreakerState.HEALTHY.value
    for tr in history:
        assert tr.src == prev, (tr, history)          # transitions chain
        assert tr.dst in legal[tr.src], (tr, history)  # and are legal
        prev = tr.dst


def random_ops(rng, n):
    ops = []
    for _ in range(n):
        r = rng.random()
        if r < 0.45:
            ops.append(("outcome", rng.random() < 0.5))
        elif r < 0.7:
            ops.append(("drift", rng.random()))
        elif r < 0.9:
            ops.append(("advance", rng.random() * 1.5))
        else:
            ops.append(("tick",))
    return ops


def test_random_event_sequences_never_produce_illegal_transitions():
    for seed in range(25):
        rng = random.Random(seed)
        h, history = run_breaker_sequence(random_ops(rng, 60))
        assert_history_legal(history)
        assert h.audit()["probes_outstanding"] == 0
        assert h.audit()["started_while_open"] == 0
