"""Binary envelope codec (protocol v1.2): round-trip fidelity + negotiation.

The contract under test: for every JSON-serializable envelope, the binary
frame decodes to EXACTLY what a JSON round-trip would produce (so the two
codecs are interchangeable per request), and malformed frames fail as
structured ``BAD_REQUEST`` — in-process as :class:`ProtocolError`, over the
wire as an HTTP 400 carrying a well-formed error envelope in whichever
codec the client asked for.

Property tests run under hypothesis when it is installed; a deterministic
seeded fuzz loop keeps the same coverage shape alive without it.
"""
import json
import random
import string

import pytest

from repro.core import ErrorCode, Orchestrator, TaskRequest, WireError
from repro.gateway import ControlPlaneGateway, protocol as wire
from repro.substrates import MemristiveAdapter

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                        # pragma: no cover
    HAVE_HYPOTHESIS = False


def json_rt(obj):
    """What the v1.1 JSON codec would deliver to the far side."""
    return json.loads(wire.dumps(obj).decode("utf-8"))


def binary_rt(obj):
    return wire.loads_binary(wire.dumps_binary(obj))


def assert_codecs_agree(envelope):
    assert binary_rt(envelope) == json_rt(envelope)


# ---------------------------------------------------------------------------
# hand-rolled round trips: every frame type the v1.2 protocol emits


SCALARS = [None, True, False, 0, 1, -1, 7, -128, 2**40, -(2**40),
           2**70, -(2**70), 0.0, -0.0, 0.5, 3.1415926535, 1e-300, 1e300,
           float("inf"), float("-inf"),
           "", "x", "plane-edge", "naïve-ünïcode-∞", "a" * 5000,
           # interned table entries used as VALUES must round-trip as strings
           "kind", "protocol_version", "retry_after_s"]


@pytest.mark.parametrize("value", SCALARS,
                         ids=[repr(v)[:32] for v in SCALARS])
def test_scalar_round_trip_matches_json(value):
    assert_codecs_agree({"v": value})


def test_nan_round_trips_as_nan():
    out = binary_rt({"v": float("nan")})["v"]
    assert out != out                           # NaN: only value ≠ itself


def test_container_round_trips_match_json():
    for env in [
        {},
        {"empty_list": [], "empty_dict": {}},
        {"nested": {"a": [1, [2, [3, {"b": None}]]]}},
        # tuple/list coercion must match json.dumps (tuples become lists)
        {"route": ("edge", "fog", "cloud")},
        # mixed list: NOT eligible for the packed-float fast path
        {"mixed": [1, 2.5, "x", None, True]},
        # pure-float list: the packed fast path must be invisible
        {"payload": [0.1, 0.2, 0.3, 0.4]},
        {"payload": [1.5] * 999},
        # non-interned keys alongside interned ones
        {"kind": "invoke", "custom_key_xyz": {"deeply": ["nested", 1.0]}},
        # non-string dict keys follow json.dumps coercion rules
        {"ints": {1: "a", 2: "b"}, "bools": {True: 1, False: 0},
         "null": {None: "n"}},
    ]:
        assert_codecs_agree(env)


def test_bytes_payloads_round_trip_raw():
    """The whole point of the binary codec: no base64/JSON re-encode."""
    blob = bytes(range(256)) * 4
    frame = wire.dumps_binary({"payload": blob})
    assert blob in frame                        # raw bytes, no re-encode
    assert wire.loads_binary(frame)["payload"] == blob
    # JSON cannot carry bytes: refusal (not silent stringification) there
    with pytest.raises(wire.ProtocolError):
        wire.dumps({"payload": blob})


def test_numpy_payloads_round_trip():
    np = pytest.importorskip("numpy")
    vec = np.linspace(-1.0, 1.0, 64)
    out = binary_rt({"payload": vec, "n": np.int64(3), "f": np.float32(0.5),
                     "m": np.ones((2, 2))})
    assert out["payload"] == pytest.approx(vec.tolist())
    assert out["n"] == 3 and out["f"] == pytest.approx(0.5)
    assert out["m"] == [[1.0, 1.0], [1.0, 1.0]]


def test_real_envelopes_round_trip():
    task = TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector", payload=[0.1, 0.2, 0.3],
                       required_telemetry=("execution_ms",),
                       metadata={"k": "v"}, latency_budget_ms=50.0)
    for env in [
        wire.request_envelope("invoke", {"task": task.to_wire(),
                                         "deadline_s": 5.0}),
        wire.request_envelope("submit_coalesced", {"entries": [
            {"task": task.to_wire(), "deadline_s": 1.0},
            {"task": task.to_wire()}]}),
        wire.request_envelope("poll_coalesced",
                              {"tickets": ["t-1", "t-2"], "wait_s": 0.5}),
        wire.ok_envelope("health", {"plane": "edge", "resources": {}}),
        wire.error_envelope("invoke",
                            WireError(ErrorCode.QUEUE_SATURATED, "full",
                                      detail={"retry_after_s": 0.25})),
    ]:
        assert_codecs_agree(env)
        restored = TaskRequest.from_wire(
            json_rt({"task": task.to_wire()})["task"])
        assert restored == task


def test_interned_fields_encode_compactly_and_are_append_only():
    # an envelope of interned keys must beat its JSON encoding on size
    env = wire.ok_envelope("poll", {"ticket": "t", "state": "done",
                                    "ok": True})
    assert len(wire.dumps_binary(env)) < len(wire.dumps(env))
    # append-only contract: the v1.2 prefix is frozen forever
    assert wire.INTERNED_FIELDS.index("protocol_version") == 0
    assert len(set(wire.INTERNED_FIELDS)) == len(wire.INTERNED_FIELDS)


def test_float_list_beats_json_size_on_tensor_payloads():
    payload = [random.Random(7).uniform(-1, 1) for _ in range(256)]
    env = {"payload": payload}
    assert len(wire.dumps_binary(env)) < len(wire.dumps(env)) / 2


# ---------------------------------------------------------------------------
# malformed frames → structured ProtocolError (never a raw struct/KeyError)


GOOD = wire.dumps_binary({"kind": "health", "ok": True, "n": [1.0, 2.0]})


@pytest.mark.parametrize("frame", [
    b"",                                        # empty
    b"\x00",                                    # bad magic
    bytes([0xA7]),                              # magic alone
    bytes([0xA7, 99]) + GOOD[2:],               # unknown codec version
    GOOD[:-1],                                  # truncated value tree
    GOOD[:3],                                   # truncated after prefix
    GOOD + b"\x00",                             # trailing bytes
    bytes([0xA7, 1, 0x01, 0xFF]),               # length prefix overruns
    bytes([0xA7, 1, 0x02, 0xFE, 0x00]),         # unknown value tag
    bytes([0xA7, 1]) + b"\xff" * 11,            # varint overflow
    bytes([0xA7, 1, 0x03, 0x0A, 0x80, 0x80]),   # interned index truncated
    wire.dumps_binary({"k": "v"})[:2] + bytes([2, 0x0A, 0x7F]),  # bad intern
    bytes([0xA7, 1, 0x05, 0x08, 0x02, 0x05,     # dict with non-str key
           0x01, 0x00]),
])
def test_malformed_frames_raise_protocol_error(frame):
    with pytest.raises(wire.ProtocolError):
        wire.loads_binary(frame)


def test_invalid_utf8_rejected():
    bad = bytearray(wire.dumps_binary({"k": "ab"}))
    assert bad[-2:] == b"ab"
    bad[-2:] = b"\xff\xfe"
    with pytest.raises(wire.ProtocolError):
        wire.loads_binary(bytes(bad))


def test_decode_envelope_sniffs_misdeclared_bodies():
    env = {"kind": "health", "ok": True}
    # binary frame declared as JSON: magic sniff routes to the binary codec
    assert wire.decode_envelope(wire.dumps_binary(env), "application/json") \
        == env
    # JSON body declared binary: fails loudly in the binary codec
    with pytest.raises(wire.ProtocolError):
        wire.decode_envelope(wire.dumps(env), wire.BINARY_CONTENT_TYPE)


def test_content_negotiation_helpers():
    assert wire.wants_binary(wire.BINARY_CONTENT_TYPE)
    assert wire.wants_binary("application/x-physmcp; q=1.0")
    assert not wire.wants_binary("application/json")
    assert not wire.wants_binary(None)
    assert not wire.wants_binary("")
    body, ctype = wire.encode_envelope({"kind": "health"}, binary=True)
    assert ctype == wire.BINARY_CONTENT_TYPE and wire.is_binary(body)
    body, ctype = wire.encode_envelope({"kind": "health"}, binary=False)
    assert ctype == wire.JSON_CONTENT_TYPE and not wire.is_binary(body)


# ---------------------------------------------------------------------------
# malformed frame OVER THE WIRE → HTTP 400 with a structured error envelope


def test_malformed_binary_frame_gets_structured_bad_request():
    orch = Orchestrator()
    orch.register(MemristiveAdapter("m-codec"))
    gw = ControlPlaneGateway(orch, plane="codec-edge").start()
    try:
        import http.client
        for accept, decoder in [
                (wire.JSON_CONTENT_TYPE, wire.loads),
                (wire.BINARY_CONTENT_TYPE, wire.loads_binary)]:
            conn = http.client.HTTPConnection("127.0.0.1", gw.port,
                                              timeout=5.0)
            try:
                conn.request("POST", "/v1/invoke", body=GOOD[:-3],
                             headers={"Content-Type":
                                      wire.BINARY_CONTENT_TYPE,
                                      "Accept": accept})
                resp = conn.getresponse()
                payload = resp.read()
                assert resp.status == 400
                env = decoder(payload)
                assert env["ok"] is False
                assert env["error"]["code"] == "BAD_REQUEST"
                assert env["protocol_version"] == wire.PROTOCOL_VERSION
            finally:
                conn.close()
    finally:
        gw.stop()


# ---------------------------------------------------------------------------
# property tests (hypothesis when available, seeded fuzz otherwise)


def _strategies():
    keys = st.one_of(st.sampled_from(wire.INTERNED_FIELDS),
                     st.text(string.printable, max_size=12))
    scalars = st.one_of(
        st.none(), st.booleans(), st.integers(),
        st.floats(allow_nan=False),
        st.text(max_size=40))
    return st.recursive(
        scalars,
        lambda children: st.one_of(
            st.lists(children, max_size=6),
            st.lists(st.floats(allow_nan=False), min_size=1, max_size=16),
            st.dictionaries(keys, children, max_size=6)),
        max_leaves=24)


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(st.dictionaries(st.text(max_size=16), _strategies(), max_size=8))
    def test_property_binary_json_equivalence(envelope):
        assert_codecs_agree(envelope)

    @settings(max_examples=200, deadline=None)
    @given(st.binary(max_size=200))
    def test_property_arbitrary_bytes_never_crash_undeclared(frame):
        """Fuzzed frames either decode or raise ProtocolError — never a
        struct/Unicode/Key/IndexError leaking out of the codec."""
        try:
            wire.loads_binary(frame)
        except wire.ProtocolError:
            pass
else:
    def _random_value(rng, depth=0):
        roll = rng.random()
        if depth >= 3 or roll < 0.45:
            return rng.choice([
                None, True, False, rng.randint(-2**48, 2**48),
                rng.uniform(-1e6, 1e6),
                "".join(rng.choices(string.printable, k=rng.randint(0, 12))),
                rng.choice(wire.INTERNED_FIELDS)])
        if roll < 0.65:
            return [rng.uniform(-1, 1) for _ in range(rng.randint(1, 12))]
        if roll < 0.8:
            return [_random_value(rng, depth + 1)
                    for _ in range(rng.randint(0, 5))]
        return {rng.choice(wire.INTERNED_FIELDS) if rng.random() < 0.5
                else "k%d" % rng.randint(0, 99):
                _random_value(rng, depth + 1)
                for _ in range(rng.randint(0, 5))}

    def test_property_binary_json_equivalence():
        rng = random.Random(0xA7)
        for _ in range(300):
            assert_codecs_agree({"body": _random_value(rng)})

    def test_property_arbitrary_bytes_never_crash_undeclared():
        rng = random.Random(0xA7)
        for _ in range(500):
            frame = bytes([0xA7, 1]) + rng.randbytes(rng.randint(0, 60))
            try:
                wire.loads_binary(frame)
            except wire.ProtocolError:
                pass
