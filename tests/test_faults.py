"""Table IV fault campaign: five scenarios, expected control-plane behavior."""
from repro.core.faults import build_campaign, run_campaign
from tests.conftest import make_testbed_factory


def test_fault_campaign_all_pass(fast_service):
    results = run_campaign(make_testbed_factory(fast_service),
                           build_campaign())
    assert len(results) == 5
    failures = [r for r in results if not r["pass"]]
    assert not failures, failures


def test_fallback_target_is_externalized_backend(fast_service):
    results = run_campaign(make_testbed_factory(fast_service),
                           build_campaign())
    by_name = {r["scenario"]: r for r in results}
    assert by_name["local_prepare_failure"]["selected"] == "fast-external"
    assert by_name["missing_telemetry"]["selected"] == "fast-external"
    # drifted case selects the externalized backend DIRECTLY (no fallback)
    drifted = by_name["drifted_local_fast"]
    assert drifted["observed"] == "success_direct"
    assert drifted["selected"] == "fast-external"


def test_rejects_happen_before_execution(fast_service):
    results = run_campaign(make_testbed_factory(fast_service),
                           build_campaign())
    by_name = {r["scenario"]: r for r in results}
    for sc in ("wetware_no_supervision", "stale_chemical_twin"):
        r = by_name[sc]
        assert r["observed"] == "reject"
        assert r["attempts"] == []      # nothing touched the substrate
