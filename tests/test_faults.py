"""Table IV fault campaign: five scenarios, expected control-plane behavior.

Includes regressions for the serial fresh-orchestrator path: the exact five
paper scenarios keep their expected outcomes with the HealthManager live,
rows carry actionable ``mismatch_reason`` strings, and the campaign leaks
no threads or resources across scenarios."""
import dataclasses
import threading
import time

from repro.core.faults import build_campaign, run_campaign
from tests.conftest import make_testbed_factory


def test_fault_campaign_all_pass(fast_service):
    results = run_campaign(make_testbed_factory(fast_service),
                           build_campaign())
    assert len(results) == 5
    failures = [r for r in results if not r["pass"]]
    assert not failures, failures


def test_fallback_target_is_externalized_backend(fast_service):
    results = run_campaign(make_testbed_factory(fast_service),
                           build_campaign())
    by_name = {r["scenario"]: r for r in results}
    assert by_name["local_prepare_failure"]["selected"] == "fast-external"
    assert by_name["missing_telemetry"]["selected"] == "fast-external"
    # drifted case selects the externalized backend DIRECTLY (no fallback)
    drifted = by_name["drifted_local_fast"]
    assert drifted["observed"] == "success_direct"
    assert drifted["selected"] == "fast-external"


def test_rejects_happen_before_execution(fast_service):
    results = run_campaign(make_testbed_factory(fast_service),
                           build_campaign())
    by_name = {r["scenario"]: r for r in results}
    for sc in ("wetware_no_supervision", "stale_chemical_twin"):
        r = by_name[sc]
        assert r["observed"] == "reject"
        assert r["attempts"] == []      # nothing touched the substrate


def test_serial_campaign_exact_paper_outcomes(fast_service):
    """Regression: the 5 paper scenarios produce EXACTLY their Table IV
    expected outcomes (not merely pass=True) on the serial path."""
    results = run_campaign(make_testbed_factory(fast_service),
                           build_campaign())
    expected = {
        "drifted_local_fast": "success_direct",
        "local_prepare_failure": "success_fallback",
        "wetware_no_supervision": "reject",
        "stale_chemical_twin": "reject",
        "missing_telemetry": "success_fallback",
    }
    assert {r["scenario"]: r["observed"] for r in results} == expected
    assert all(r["mismatch_reason"] is None for r in results)


def test_serial_campaign_leaks_no_threads_or_slots(fast_service):
    """Each scenario's fresh orchestrator must leave nothing running:
    thread count is unchanged after the campaign (no scheduler/worker or
    ticker threads leak across scenarios) and no slots stay held."""
    factory = make_testbed_factory(fast_service)
    orchestrators = []

    def tracking_factory():
        orch = factory()
        orchestrators.append(orch)
        return orch

    before = set(threading.enumerate())
    run_campaign(tracking_factory, build_campaign())
    # transient threads (e.g. per-request HTTP handlers of the shared test
    # service) may take a moment to exit; a leaked scheduler worker or
    # health ticker would block forever and still fail here
    deadline = time.monotonic() + 5.0
    while True:
        leaked = [t for t in set(threading.enumerate()) - before
                  if t.is_alive()]
        if not leaked or time.monotonic() > deadline:
            break
        time.sleep(0.05)
    assert not leaked, [t.name for t in leaked]
    for orch in orchestrators:
        assert orch.policy.fully_released(), orch.policy.outstanding()


def test_mismatch_reason_is_actionable(fast_service):
    """A failing row must explain itself: wrong outcome and wrong target
    both produce a populated mismatch_reason (pass stays False)."""
    scenarios = build_campaign()
    wrong_outcome = dataclasses.replace(scenarios[0], expected="reject")
    wrong_target = dataclasses.replace(scenarios[0],
                                       target_hint="wetware-synthetic")
    results = run_campaign(make_testbed_factory(fast_service),
                           [wrong_outcome, wrong_target])
    assert not results[0]["pass"]
    assert "expected 'reject'" in results[0]["mismatch_reason"]
    assert "success_direct" in results[0]["mismatch_reason"]
    assert not results[1]["pass"]
    assert "target_hint" in results[1]["mismatch_reason"]
    assert "fast-external" in results[1]["mismatch_reason"]
