"""Data-plane adapter behaviour: chemical, wetware, memristive, HTTP, CL."""
import numpy as np
import pytest

from repro.core import Orchestrator, TaskRequest
from repro.core.invocation import RESULT_KEYS
from repro.core import shared_key_ratio
from repro.substrates import standard_testbed


def _submit(orch, **kw):
    res, trace = orch.submit(TaskRequest(**kw))
    return res, trace


def test_chemical_lifecycle_and_telemetry(orchestrator):
    res, _ = _submit(orchestrator, function="assay",
                     input_modality="concentration",
                     output_modality="concentration",
                     payload={"concentrations": [0.9, 0.1, 0.1, 0.1]},
                     required_telemetry=("convergence_ms", "contamination"))
    assert res.status == "completed"
    assert res.resource_id == "chemical-ode"
    # winner-take-all: highest input concentration wins
    assert res.output["winner"] == 0
    assert res.telemetry["contamination"] > 0.0
    assert res.telemetry["convergence_ms"] > 0.0


def test_chemical_contamination_accumulates_and_flush_resets(orchestrator):
    adapter = orchestrator.registry.adapter("chemical-ode")
    for _ in range(3):
        _submit(orchestrator, function="assay",
                input_modality="concentration",
                output_modality="concentration",
                payload={"concentrations": [0.5, 0.5, 0.2, 0.2]},
                required_telemetry=("convergence_ms",))
    assert adapter.contamination > 0.05
    adapter.reset("flush")
    assert adapter.contamination == 0.0


def test_wetware_viability_sensitivity(orchestrator):
    adapter = orchestrator.registry.adapter("wetware-synthetic")
    v0 = adapter.viability
    res, _ = _submit(orchestrator, function="screening",
                     input_modality="spikes", output_modality="spikes",
                     payload={"pattern": [1, 1, 0, 1], "amplitude": 1.5},
                     required_telemetry=("viability", "firing_rate_hz"))
    assert res.status == "completed"
    assert adapter.viability < v0
    assert res.telemetry["firing_rate_hz"] >= 0.0
    assert "fingerprint" in res.output


def test_wetware_stimulation_safety_bound(orchestrator):
    res, trace = _submit(orchestrator, function="screening",
                         input_modality="spikes", output_modality="spikes",
                         payload={"pattern": [1], "amplitude": 5.0},
                         metadata={"stimulation_amplitude": 5.0},
                         allow_fallback=False)
    assert res.status == "rejected"
    assert "safety bound" in trace.rejected_reason or \
           "safety bound" in res.telemetry.get("reason", "")


def test_memristive_drift_and_reprogram(orchestrator):
    adapter = orchestrator.registry.adapter("memristive-local")
    for _ in range(12):
        _submit(orchestrator, function="inference", input_modality="vector",
                output_modality="vector", payload=[0.1, 0.2, 0.3, 0.4],
                required_telemetry=("execution_ms",))
    assert adapter.twin.drift() > 0.05
    adapter.reset("reprogram")
    assert adapter.twin.drift() < 1e-9


def test_invocation_result_shared_keys_across_backends(orchestrator):
    """RQ1: invocation shared-key ratio 1.0 across executable backends."""
    results = []
    results.append(_submit(orchestrator, function="inference",
                           input_modality="vector", output_modality="vector",
                           payload=[0.1, 0.2, 0.3, 0.4])[0])
    results.append(_submit(orchestrator, function="assay",
                           input_modality="concentration",
                           output_modality="concentration",
                           payload={"concentrations": [0.4, 0.2, 0.1, 0.3]})[0])
    results.append(_submit(orchestrator, function="screening",
                           input_modality="spikes", output_modality="spikes",
                           payload={"pattern": [1, 0, 1]})[0])
    results.append(_submit(orchestrator, function="inference",
                           input_modality="vector", output_modality="vector",
                           backend_preference="fast-external",
                           payload=[0.3, 0.3, 0.3, 0.3])[0])
    assert {r.resource_id for r in results} >= {
        "memristive-local", "chemical-ode", "fast-external"}
    dicts = [r.to_dict() for r in results]
    assert shared_key_ratio(dicts) == 1.0
    for d in dicts:
        assert set(d.keys()) == set(RESULT_KEYS)


def test_twin_plane_tracks_results(orchestrator):
    tw = orchestrator.twins.get("memristive-local")
    obs0 = tw.observations
    _submit(orchestrator, function="inference", input_modality="vector",
            output_modality="vector", payload=[0.5, 0.1, 0.1, 0.1])
    assert tw.observations > obs0
    assert tw.age_ms() < 5_000.0
