"""Scenario simulator: invariant auditors, determinism, zero real sleeps.

The auditors are tested for FALSIFIABILITY first: each one must catch a
hand-injected violation in a mock trace (an auditor that cannot fail is
not a test).  Then live small-fleet runs assert the real simulator holds
every invariant, reproduces identical trace hashes for identical seeds,
and performs zero real sleeps on the simulated path.

The seeded-determinism regression for ``run_campaign_concurrent`` lives
here too: fixed seed + virtual clock + one worker ⇒ identical classified
outcomes and identical campaign trace hashes across runs.
"""
import pytest

from repro.core import Orchestrator, TaskRequest
from repro.core.faults import (ChaosScenario, campaign_trace_hash,
                               inject_drift, inject_invoke_failure,
                               run_campaign_concurrent)
from repro.core.simclock import VirtualClock
from repro.core.simulator import (AUDITORS, FleetSimulator,
                                  audit_breaker_legality,
                                  audit_budget_arithmetic,
                                  audit_policy_slots,
                                  audit_session_uniqueness,
                                  audit_twin_validity,
                                  cascading_breaker_storm, diurnal_wave,
                                  event_trace_hash, regional_partition,
                                  rolling_protocol_upgrade, run_audits,
                                  scenario_matrix, twin_fidelity_collapse)
from tests.test_scheduler_concurrency import SyntheticAdapter

pytestmark = pytest.mark.sim


# ---------------------------------------------------------------------------
# auditor falsifiability: every auditor must catch an injected violation


def _breaker_ev(src, dst, rid="r0", plane="p0"):
    return {"t": 0.0, "kind": "breaker", "plane": plane, "rid": rid,
            "src": src, "dst": dst, "reason": "test"}


def test_breaker_auditor_accepts_legal_trajectory():
    trace = [_breaker_ev("healthy", "degraded"),
             _breaker_ev("degraded", "open"),
             _breaker_ev("open", "probation"),
             _breaker_ev("probation", "healthy")]
    assert audit_breaker_legality(trace) == []


def test_breaker_auditor_catches_illegal_transition():
    # open -> healthy skips probation: illegal
    trace = [_breaker_ev("healthy", "open"),
             _breaker_ev("open", "healthy")]
    violations = audit_breaker_legality(trace)
    assert any("illegal breaker transition" in v for v in violations)


def test_breaker_auditor_catches_discontinuity():
    # second transition claims src=degraded but last recorded state is open
    trace = [_breaker_ev("healthy", "open"),
             _breaker_ev("degraded", "open")]
    violations = audit_breaker_legality(trace)
    assert any("discontinuity" in v for v in violations)


def test_breaker_auditor_tracks_resources_independently():
    trace = [_breaker_ev("healthy", "open", rid="a"),
             _breaker_ev("healthy", "degraded", rid="b")]
    assert audit_breaker_legality(trace) == []


def _twin_serve_ev(**overrides):
    ev = {"t": 0.0, "kind": "twin_serve", "session": "s0", "rid": "r0",
          "plane": "p0", "valid": True, "reason": "ok", "age_ms": 10.0,
          "max_age_ms": 1000.0, "confidence": 0.9, "min_confidence": 0.3,
          "invalidation_reason": None}
    ev.update(overrides)
    return ev


def test_twin_auditor_accepts_valid_serve():
    assert audit_twin_validity([_twin_serve_ev()]) == []


@pytest.mark.parametrize("mutation,needle", [
    ({"valid": False}, "flagged invalid"),
    ({"invalidation_reason": "collapsed"}, "invalidated"),
    ({"age_ms": 5000.0}, "stale"),
    ({"confidence": 0.1}, "confidence floor"),
])
def test_twin_auditor_catches_each_invalid_evidence(mutation, needle):
    violations = audit_twin_validity([_twin_serve_ev(**mutation)])
    assert any(needle in v for v in violations), violations


def _hop_ev(**overrides):
    ev = {"t": 0.0, "kind": "hop", "session": "s0", "src": "p0", "dst": "p1",
          "hop_before": 8, "hop_after": 7, "budget_before": 60.0,
          "budget_after": 55.0, "margin_ms": 5.0}
    ev.update(overrides)
    return ev


def test_budget_auditor_accepts_exact_arithmetic():
    assert audit_budget_arithmetic([_hop_ev()]) == []


@pytest.mark.parametrize("mutation,needle", [
    ({"hop_after": 8}, "hop budget"),                 # forgot to decrement
    ({"hop_after": 6}, "hop budget"),                 # double decrement
    ({"budget_after": 55.000001}, "inexact"),         # off by epsilon
    ({"budget_after": 50.0}, "inexact"),              # double margin
    ({"budget_before": None}, "from nowhere"),        # budget materialized
])
def test_budget_auditor_catches_each_off_by_one(mutation, needle):
    violations = audit_budget_arithmetic([_hop_ev(**mutation)])
    assert any(needle in v for v in violations), violations


def _slot_evs(session="s0", rid="r0", plane="p0"):
    base = {"t": 0.0, "plane": plane, "rid": rid, "session": session}
    return (dict(base, kind="slot_acquire"), dict(base, kind="slot_release"))


def test_slot_auditor_accepts_balanced_sequences():
    a, r = _slot_evs()
    a2, r2 = _slot_evs(session="s1")
    assert audit_policy_slots([a, a2, r, r2]) == []


def test_slot_auditor_catches_leak():
    a, _ = _slot_evs()
    violations = audit_policy_slots([a])
    assert any("leaked" in v for v in violations)


def test_slot_auditor_catches_release_without_acquire():
    _, r = _slot_evs()
    violations = audit_policy_slots([r])
    assert any("without acquire" in v for v in violations)


def test_slot_auditor_catches_cross_session_imbalance():
    a, _ = _slot_evs(session="s0")
    _, r = _slot_evs(session="s1")
    violations = audit_policy_slots([a, r])
    assert any("imbalance" in v for v in violations)


def test_session_auditor_catches_duplicates():
    evs = [{"kind": "session", "session": "s0"},
           {"kind": "session", "session": "s1"},
           {"kind": "session", "session": "s0"}]
    violations = audit_session_uniqueness(evs)
    assert any("duplicate" in v for v in violations)
    assert audit_session_uniqueness(evs[:2]) == []


def test_run_audits_covers_every_registered_auditor():
    out = run_audits([])
    assert set(out) == set(AUDITORS)
    assert all(v == [] for v in out.values())


# ---------------------------------------------------------------------------
# live small-fleet runs


def test_small_fleet_holds_every_invariant():
    sc = cascading_breaker_storm(planes=30, substrates_per_plane=4,
                                 duration_s=200.0)
    report = FleetSimulator(sc, seed=3).run()
    assert report["violations_total"] == 0, report["violations"]
    assert report["real_sleep_calls"] == 0
    assert report["tasks"] > 0
    # the storm really exercised the breaker lifecycle
    assert report["breaker_transitions"] > 0
    assert report["outcomes"].get("completed", 0) > 0


def test_same_seed_reproduces_identical_trace_hash():
    mk = lambda: diurnal_wave(planes=20, substrates_per_plane=3,
                              duration_s=150.0)
    r1 = FleetSimulator(mk(), seed=42).run()
    r2 = FleetSimulator(mk(), seed=42).run()
    assert r1["trace_hash"] == r2["trace_hash"]
    assert r1["outcomes"] == r2["outcomes"]
    r3 = FleetSimulator(mk(), seed=43).run()
    assert r3["trace_hash"] != r1["trace_hash"]


def test_twin_collapse_refuses_invalid_twins_live():
    sc = twin_fidelity_collapse(planes=24, substrates_per_plane=4,
                                duration_s=300.0)
    sim = FleetSimulator(sc, seed=5)
    report = sim.run()
    assert report["violations_total"] == 0, report["violations"]
    # the collapse forced twin consultations, and every one against an
    # invalidated twin was REFUSED (zero serves from invalid twins)
    assert report["outcomes"].get("twin_refused", 0) > 0
    refusals = [ev for ev in sim.trace if ev["kind"] == "twin_refused"]
    assert any(ev["invalidation_reason"] for ev in refusals)


def test_partition_drops_and_heals():
    sc = regional_partition(planes=24, substrates_per_plane=3,
                            duration_s=300.0)
    sim = FleetSimulator(sc, seed=9)
    report = sim.run()
    assert report["violations_total"] == 0, report["violations"]
    assert report["outcomes"].get("partition_drop", 0) > 0
    # traffic flows again after the heal event
    heal_t = [ev["t"] for ev in sim.trace
              if ev["kind"] == "scenario_event" and ev["action"] == "heal_region"]
    assert heal_t
    assert any(ev["kind"] == "outcome" and ev["t"] > heal_t[0]
               for ev in sim.trace)


def test_rolling_upgrade_negotiates_mixed_versions():
    sc = rolling_protocol_upgrade(planes=24, substrates_per_plane=3,
                                  duration_s=300.0)
    report = FleetSimulator(sc, seed=13).run()
    assert report["violations_total"] == 0, report["violations"]
    # the mixed-fleet window produced cross-version forwarding pairs
    pairs = [tuple(k.split("->")) for k in report["proto_pairs"]]
    assert any(a != b for a, b in pairs), report["proto_pairs"]
    versions = {v for pair in pairs for v in pair}
    assert {"v1.0", "v1.1"} <= versions


def test_scenario_matrix_spans_all_builders():
    matrix = scenario_matrix(planes=10, substrates_per_plane=2,
                             duration_s=60.0)
    assert len(matrix) == 6
    assert len({sc.name for sc in matrix}) == 6
    assert all(sc.planes == 10 for sc in matrix)


def test_trace_hash_sensitive_to_any_event_field():
    base = [{"t": 1.0, "kind": "session", "session": "s0"}]
    assert event_trace_hash(base) != event_trace_hash(
        [{"t": 1.0, "kind": "session", "session": "s1"}])
    assert event_trace_hash(base) != event_trace_hash(
        [{"t": 2.0, "kind": "session", "session": "s0"}])
    assert event_trace_hash(base) == event_trace_hash(
        [dict(base[0])])


# ---------------------------------------------------------------------------
# seeded determinism of the concurrent chaos campaign (regression)


def _campaign():
    def vec(i):
        return TaskRequest(function="inference", input_modality="vector",
                           output_modality="vector",
                           payload=[0.2, 0.4, 0.1, 0.3])
    return [
        ChaosScenario(
            name="invoke_failure_readmit",
            injector=inject_invoke_failure("syn-a"),
            template=vec, n_tasks=6,
            expected=("success_fallback", "success_direct"),
            breaker_rid="syn-a",
            expect_trajectory=("open", "probation", "healthy")),
        ChaosScenario(
            name="drift_reroute",
            injector=inject_drift("syn-a", 0.8),
            template=vec, n_tasks=4,
            expected=("success_direct",),
            target_hint="syn-b",
            breaker_rid="syn-a",
            expect_trajectory=("open", "probation", "healthy")),
    ]


def _run_seeded_campaign(seed):
    orch = Orchestrator(health={"cooldown_s": 0.2, "probes_to_close": 2},
                        clock=VirtualClock())
    orch.register(SyntheticAdapter("syn-a", 2, dwell_s=0.0))
    orch.register(SyntheticAdapter("syn-b", 2, dwell_s=0.0))
    return run_campaign_concurrent(orch, _campaign(), workers=1, seed=seed)


@pytest.mark.chaos
def test_campaign_seeded_virtual_clock_is_deterministic():
    r1 = _run_seeded_campaign(seed=7)
    r2 = _run_seeded_campaign(seed=7)
    assert r1["all_pass"], [r for r in r1["rows"] if not r["pass"]]
    assert r1["seed"] == 7 and "trace_hash" in r1
    # identical classified outcomes AND identical event-trace hashes
    assert r1["rows"] == r2["rows"]
    assert r1["trace_hash"] == r2["trace_hash"]
    # the hash is not vacuous: it reflects the campaign content
    assert r1["trace_hash"] != campaign_trace_hash([])


@pytest.mark.chaos
def test_campaign_hash_ignores_volatile_timing_keys():
    rows = [{"scenario": "s", "observed": {"success_direct": 2},
             "latency_ms": 12.5, "wall_s": 0.1, "pass": True}]
    rows2 = [{"scenario": "s", "observed": {"success_direct": 2},
              "latency_ms": 99.9, "wall_s": 4.2, "pass": True}]
    assert campaign_trace_hash(rows) == campaign_trace_hash(rows2)
    rows3 = [{"scenario": "s", "observed": {"success_direct": 3},
              "latency_ms": 12.5, "wall_s": 0.1, "pass": True}]
    assert campaign_trace_hash(rows) != campaign_trace_hash(rows3)
