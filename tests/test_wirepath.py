"""Sub-millisecond wire path: coalescing, mixed codecs, shared followers.

Integration coverage for the v1.2 wire-path rework on live gateways:

- mixed-codec federation — a JSON parent hop and a binary child hop in one
  device→edge→cloud chain, proving codec negotiation is per connection
  (per request, in fact) and that federated forwards ride the coalesced
  submit/poll endpoints;
- the client's bounded per-thread connection pool — thread churn must not
  leak sockets (dead owners reaped, LRU evicted beyond the cap);
- coalesced execution end-to-end — group commit visibly batches concurrent
  submitters, outcomes are per-entry, resolved tickets deliver once;
- the shared stream follower — ``federate_all`` profiles of one child
  plane share ONE ``/v1/stream`` subscription that dies with its last
  subscriber, not its first.
"""
import threading
import time

import pytest

from repro.core import ErrorCode, Orchestrator, TaskRequest
from repro.gateway import ControlPlaneClient, ControlPlaneGateway, GatewayError
from repro.substrates import (ChemicalAdapter, MemristiveAdapter,
                              federate, federate_all)
from repro.substrates.remote_plane import _PlaneStreamFollower


def _vector_task(**kw):
    return TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector", payload=[0.1, 0.2, 0.3, 0.4],
                       **kw)


def _await(cond, timeout_s=5.0, interval_s=0.02):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(interval_s)
    return cond()


@pytest.fixture()
def edge_plane():
    orch = Orchestrator()
    orch.register(MemristiveAdapter("edge-m"))
    gw = ControlPlaneGateway(orch, plane="wire-edge").start()
    try:
        yield orch, gw
    finally:
        gw.stop()


# ---------------------------------------------------------------------------
# mixed-codec federation: JSON parent hop, binary child hop


def test_mixed_codec_federation_json_parent_binary_child(edge_plane):
    _, edge_gw = edge_plane
    cloud = Orchestrator()
    cloud_gw = ControlPlaneGateway(cloud, plane="wire-cloud").start()
    binary_child = ControlPlaneClient(edge_gw.url, codec="binary")
    json_parent = ControlPlaneClient(cloud_gw.url)      # wire-identical v1.1
    try:
        adapter = federate(cloud, binary_child)
        res, trace = json_parent.invoke(_vector_task(), deadline_s=30.0)
        assert res.status == "completed"
        assert trace.selected == adapter.resource_id
        assert res.artifacts["remote_trace"]["selected"] == "edge-m"
        # the child hop really negotiated the binary codec AND rode the
        # coalesced submit buffer (the v1.2 federated fast path)
        assert binary_child.codec == "binary"
        assert binary_child._coalescer.entries >= 1
        assert binary_child._coalescer.flushes >= 1
        # same chain again, pure JSON child: results agree across codecs
        res2, _ = json_parent.invoke(_vector_task(), deadline_s=30.0)
        assert res2.status == "completed"
        assert len(res2.output) == len(res.output)
    finally:
        json_parent.close()
        binary_child.close()
        cloud_gw.stop()


@pytest.mark.parametrize("codec", ["json", "binary"])
def test_full_read_surface_per_codec(edge_plane, codec):
    """Every GET/POST endpoint answers identically under either codec."""
    _, gw = edge_plane
    client = ControlPlaneClient(gw.url, codec=codec)
    try:
        assert client.health()["plane"] == "wire-edge"
        fleet = client.discover()
        assert [d.resource_id for d in fleet] == ["edge-m"]
        described = client.describe("edge-m")
        assert described["descriptor"].resource_id == "edge-m"
        res, trace = client.invoke(_vector_task(), deadline_s=30.0)
        assert res.status == "completed" and trace.selected == "edge-m"
        ticket = client.submit(_vector_task(), deadline_s=30.0)
        out_res, _ = client.result(ticket, timeout_s=30.0)
        assert out_res.status == "completed"
    finally:
        client.close()


# ---------------------------------------------------------------------------
# connection pool: churn must not leak, cap must hold


def test_thread_churn_does_not_leak_pooled_sockets(edge_plane):
    _, gw = edge_plane
    client = ControlPlaneClient(gw.url)
    seen = []
    try:
        def one_call():
            client.health()
            with client._pool_lock:
                entry = client._pool.get(threading.get_ident())
            if entry is not None:
                seen.append(entry[1])

        for _ in range(3 * ControlPlaneClient.MAX_POOLED_CONNS):
            t = threading.Thread(target=one_call)
            t.start()
            t.join(timeout=10.0)
            assert not t.is_alive()
        assert len(seen) == 3 * ControlPlaneClient.MAX_POOLED_CONNS
        # any pool lookup reaps dead owners: the churned sockets close
        client.health()
        with client._pool_lock:
            assert len(client._pool) <= 2   # this thread (+ mux, if woken)
        dead = [c for c in seen if c.sock is not None]
        assert _await(lambda: all(c.sock is None for c in seen)), \
            f"{len(dead)} sockets from exited threads still open"
    finally:
        client.close()


def test_pool_cap_bounds_live_threads(edge_plane):
    _, gw = edge_plane
    client = ControlPlaneClient(gw.url)
    hold = threading.Event()
    started = threading.Barrier(ControlPlaneClient.MAX_POOLED_CONNS + 8 + 1,
                                timeout=30.0)
    threads = []
    try:
        def one_call():
            client.health()
            started.wait()
            hold.wait(timeout=30.0)

        for _ in range(ControlPlaneClient.MAX_POOLED_CONNS + 8):
            t = threading.Thread(target=one_call, daemon=True)
            t.start()
            threads.append(t)
        started.wait()
        # every owner is still alive, so the LRU cap is the only bound
        client.health()
        with client._pool_lock:
            assert len(client._pool) <= ControlPlaneClient.MAX_POOLED_CONNS + 1
    finally:
        hold.set()
        for t in threads:
            t.join(timeout=10.0)
        client.close()


# ---------------------------------------------------------------------------
# coalesced execution end-to-end


def test_group_commit_batches_concurrent_submitters(edge_plane):
    _, gw = edge_plane
    client = ControlPlaneClient(gw.url, coalesce_linger_s=0.05)
    n = 8
    tickets = [None] * n
    try:
        def submit(i):
            tickets[i] = client.submit_coalesced(_vector_task())

        threads = [threading.Thread(target=submit, args=(i,))
                   for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30.0)
        assert all(isinstance(t, str) for t in tickets)
        assert len(set(tickets)) == n
        co = client._coalescer
        assert co.entries == n
        assert co.flushes < n, \
            f"no batching happened ({co.flushes} flushes for {n} entries)"
        # one frame polls them all; resolved tickets deliver exactly once
        outcomes = client.poll_coalesced(tickets, wait_s=30.0)
        assert [o["ticket"] for o in outcomes] == tickets
        done = [o for o in outcomes if o.get("state") == "done"]
        for out in done:
            assert out["ok"] and out["result"]["status"] == "completed"
        again = client.poll_coalesced([o["ticket"] for o in done])
        assert all(not o["ok"] and o["error"]["code"] == "NOT_FOUND"
                   for o in again)
    finally:
        client.close()


def test_coalesced_outcomes_are_per_entry(edge_plane):
    """One malformed entry fails only its own slot — the strangers sharing
    its frame keep their tickets (unlike atomic ``submit_many``)."""
    from repro.gateway import protocol as wire

    _, gw = edge_plane
    client = ControlPlaneClient(gw.url)
    try:
        good = _vector_task()
        body = client._call("POST", "/v1/submit_coalesced",
                            wire.request_envelope("submit_coalesced", {
                                "entries": [
                                    {"task": wire.task_to_wire(good)},
                                    {"no_task_here": 1},
                                ]}))
        outcomes = body["outcomes"]
        assert len(outcomes) == 2
        assert "ticket" in outcomes[0]          # the stranger survives
        assert outcomes[1]["error"]["code"] == "BAD_REQUEST"
        res, _ = client.result(outcomes[0]["ticket"], timeout_s=30.0)
        assert res.status == "completed"
        # a task no resource can serve fails AT EXECUTION, per-ticket
        bad = TaskRequest(function="inference", input_modality="spikes",
                          output_modality="spikes", payload=[1.0])
        with pytest.raises(GatewayError) as exc:
            client.invoke_coalesced(bad, deadline_s=10.0)
        assert exc.value.code == ErrorCode.NO_MATCH
    finally:
        client.close()


def test_invoke_coalesced_concurrent_waiters_share_the_mux(edge_plane):
    _, gw = edge_plane
    client = ControlPlaneClient(gw.url, codec="binary")
    n = 8
    results = [None] * n
    try:
        def call(i):
            res, trace = client.invoke_coalesced(_vector_task(),
                                                 deadline_s=30.0)
            results[i] = (res, trace)

        threads = [threading.Thread(target=call, args=(i,))
                   for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60.0)
        assert all(r is not None for r in results)
        for res, trace in results:
            assert res.status == "completed"
            assert trace.selected == "edge-m"
    finally:
        client.close()


# ---------------------------------------------------------------------------
# shared stream follower: one subscription per child plane


def _follow_threads(gw):
    want = f"phys-mcp-follow-127.0.0.1:{gw.port}"
    return [t for t in threading.enumerate()
            if t.is_alive() and t.name == want]


def test_federate_all_shares_one_follower_per_child_plane():
    edge = Orchestrator()
    edge.register(MemristiveAdapter("edge-m"))
    edge.register(ChemicalAdapter())
    gw = ControlPlaneGateway(edge, plane="multi-edge").start()
    cloud = Orchestrator()
    try:
        adapters = federate_all(cloud, gw.url)
        assert len(adapters) == 2               # vector + concentration
        a1, a2 = adapters
        # ONE follower object, ONE registry slot, ONE stream thread
        assert a1._follower is a2._follower
        assert len(_follow_threads(gw)) == 1
        # ...and both profile adapters still see connects + live health
        assert _await(lambda: a1._stream_connects >= 1
                      and a2._stream_connects >= 1)
        assert _await(lambda: a1.snapshot().health_status == "healthy"
                      and a2.snapshot().health_status == "healthy")

        # closing ONE profile keeps the sibling streaming
        follower = a1._follower
        a1.close()
        assert a1._follower is None
        assert a2._follower is follower
        assert len(_follow_threads(gw)) == 1
        assert _PlaneStreamFollower._registry.get(
            ("127.0.0.1", gw.port)) is follower

        # the LAST subscriber tears the stream down and drops the registry
        a2.close()
        assert _await(lambda: not _follow_threads(gw))
        assert ("127.0.0.1", gw.port) not in _PlaneStreamFollower._registry
    finally:
        gw.stop()


def test_follower_reconnect_fans_out_to_all_profiles():
    edge = Orchestrator()
    edge.register(MemristiveAdapter("edge-m"))
    edge.register(ChemicalAdapter())
    gw = ControlPlaneGateway(edge, plane="flap-edge").start()
    port = gw.port
    cloud = Orchestrator()
    adapters = federate_all(cloud, gw.url)
    a1, a2 = adapters
    try:
        assert _await(lambda: a1._stream_connects >= 1
                      and a2._stream_connects >= 1)
        gw.stop()
        # stream loss marks EVERY profile down (wire-free, no poll lag)
        assert _await(lambda: a1.snapshot().readiness == "down"
                      and a2.snapshot().readiness == "down")
        gw = ControlPlaneGateway(edge, plane="flap-edge", port=port).start()
        # the shared follower reconnects once; BOTH adapters observe it
        assert _await(lambda: a1._stream_connects >= 2
                      and a2._stream_connects >= 2, timeout_s=8.0)
        assert _await(lambda: a1.snapshot().health_status == "healthy"
                      and a2.snapshot().health_status == "healthy")
        assert len(_follow_threads(gw)) == 1
    finally:
        for a in adapters:
            a.close()
        gw.stop()
