"""Regression tests for orchestrator accounting and task-copy semantics.

Covers the seed bugs fixed by the concurrent-control-plane refactor:
- control overhead (initial matcher time) folded into the trace on the
  success path, not only on rejection;
- ``Orchestrator.submit`` annotated with a real ``Tuple[...]`` type, not a
  throwaway ``(A, B)`` expression;
- ``_next_candidate``'s task copy no longer aliases the caller's
  ``metadata`` dict.
"""
import dataclasses
import typing

from repro.core import Orchestrator, TaskRequest
from repro.core.invocation import InvocationResult
from repro.core.orchestrator import OrchestrationTrace
from tests.test_scheduler_concurrency import SyntheticAdapter


def _task(**kw):
    kw.setdefault("function", "inference")
    kw.setdefault("input_modality", "vector")
    kw.setdefault("output_modality", "vector")
    return TaskRequest(**kw)


def test_control_overhead_counted_on_success_path():
    orch = Orchestrator()
    orch.register(SyntheticAdapter("syn-a", 2, dwell_s=0.0))
    res, trace = orch.submit(_task())
    assert res.status == "completed"
    # the initial matcher select is real work; overhead must be non-trivial
    # on the success path (the seed only accounted it on rejection)
    assert trace.control_overhead_ms > 0.0


def test_control_overhead_counted_on_rejection_path():
    orch = Orchestrator()
    orch.register(SyntheticAdapter("syn-a", 2, dwell_s=0.0))
    res, trace = orch.submit(_task(function="nonexistent"))
    assert res.status == "rejected"
    assert trace.control_overhead_ms > 0.0


def test_queue_wait_reported_separately_from_overhead():
    orch = Orchestrator()
    orch.register(SyntheticAdapter("syn-a", 2, dwell_s=0.0))
    _, trace = orch.submit(_task())
    assert trace.queue_wait_ms >= 0.0


def test_submit_return_annotation_is_a_real_type():
    hints = typing.get_type_hints(Orchestrator.submit)
    assert hints["return"] == typing.Tuple[InvocationResult,
                                           OrchestrationTrace]


def test_fallback_task_copy_does_not_alias_metadata():
    orch = Orchestrator()
    orch.register(SyntheticAdapter("syn-a", 2, dwell_s=0.0))
    # drive through the public path: a preferred backend that fails prepare
    # forces _next_candidate to build the fallback copy
    bad = SyntheticAdapter("syn-bad", 1, dwell_s=0.0)
    bad.inject_fault("prepare_failure")
    orch.register(bad)
    task = _task(metadata={"k": "v"}, backend_preference="syn-bad")
    res, trace = orch.submit(task)
    assert res.status == "completed"
    assert trace.fallback_used
    # the caller's task object is untouched by the fallback path
    assert task.backend_preference == "syn-bad"
    assert task.metadata == {"k": "v"}


def test_trace_is_a_plain_serializable_dataclass():
    orch = Orchestrator()
    orch.register(SyntheticAdapter("syn-a", 2, dwell_s=0.0))
    _, trace = orch.submit(_task())
    d = dataclasses.asdict(trace)      # must not contain unpicklable fields
    assert d["task_id"] == trace.task_id
    assert d["attempts"]


def test_next_candidate_copy_is_independent():
    orch = Orchestrator()
    orch.register(SyntheticAdapter("syn-a", 2, dwell_s=0.0))
    task = _task(metadata={"k": "v"}, backend_preference="syn-a")
    cand = orch._next_candidate(task, tried=set())
    # the original task keeps its preference and its own metadata dict
    assert task.backend_preference == "syn-a"
    assert task.metadata == {"k": "v"}
    assert cand is not None and cand.resource_id == "syn-a"
