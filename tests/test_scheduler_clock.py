"""Scheduler timebase regression suite: the busy-wait is gone.

The scheduler's backpressure used to poll ``time.sleep(0.01)`` on a full
queue and its deadline math read ``time.monotonic()`` directly.  Both now
go through the injected Clock:

- a producer blocked on a full queue parks on a condition and wakes on
  the worker's notify — zero ``time.sleep`` calls anywhere on the
  control path;
- deadlines lapse on the *injected* timebase: under a VirtualClock,
  advancing virtual time is sufficient for a queued task's deadline to
  be detected — no wall-clock polling drift involved.
"""
import inspect
import threading

import pytest

from repro.core import ControlPlaneScheduler, Orchestrator, TaskRequest
from repro.core import scheduler as scheduler_module
from repro.core.errors import ErrorCode
from repro.core.simclock import VirtualClock, forbid_real_sleep
from tests.test_scheduler_concurrency import SyntheticAdapter

pytestmark = pytest.mark.sim


def _task(i: int) -> TaskRequest:
    return TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector",
                       payload=[0.2, 0.4, 0.1, 0.3])


def test_scheduler_module_has_no_direct_time_dependency():
    """Source-level regression guard: the scheduler must not import the
    ``time`` module at all — every read goes through the injected Clock,
    so there is no path back to a hidden ``time.sleep`` poll."""
    src = inspect.getsource(scheduler_module)
    assert "import time" not in src
    assert "time.sleep" not in src
    assert "time.monotonic" not in src


def test_backpressure_parks_without_any_real_sleep():
    """queue_size=1, workers=1, a gated adapter: the third producer must
    block for queue space and be woken by the worker's dequeue notify.
    The entire episode performs ZERO ``time.sleep`` calls (the old
    implementation would have polled at 10ms intervals)."""
    orch = Orchestrator(health=False)
    gate = threading.Event()
    adapter = SyntheticAdapter("syn-gated", 1, dwell_s=0.0)
    inner = SyntheticAdapter.invoke

    def gated_invoke(session):
        gate.wait(timeout=30)
        with adapter._mu:
            adapter.invocations += 1
        return {"output": {"echo": session.task.payload},
                "telemetry": {"drift_score": 0.0,
                              "health_status": "healthy",
                              "observation_ms": 0.0},
                "artifacts": {}, "backend_ms": 0.0}

    adapter.invoke = gated_invoke
    del inner
    orch.register(adapter)

    with forbid_real_sleep(strict=False) as counter:
        with ControlPlaneScheduler(orch, workers=1, queue_size=1,
                                   health_tick_interval_s=0.0) as sched:
            futs = [sched.submit_async(_task(0)),
                    sched.submit_async(_task(1))]
            blocked = {"fut": None}

            def producer():
                blocked["fut"] = sched.submit_async(_task(2))

            t = threading.Thread(target=producer)
            t.start()
            t.join(timeout=0.2)
            # the producer is parked on the space condition: the queue is
            # full and the worker is gated inside task 0
            assert t.is_alive()
            gate.set()
            t.join(timeout=30)
            assert not t.is_alive()
            futs.append(blocked["fut"])
            results = [f.result(timeout=30) for f in futs]
    assert all(r.status == "completed" for r, _ in results)
    assert counter["calls"] == 0, \
        f"control path performed {counter['calls']} real sleep(s)"


def test_deadline_lapse_detected_on_virtual_time():
    """A queued task whose deadline lapses in VIRTUAL time is rejected
    with the structured DEADLINE code the moment the worker reaches it —
    detection needs no wall-clock passage and no polling."""
    vclock = VirtualClock()
    orch = Orchestrator(health=False, clock=vclock)
    gate = threading.Event()
    adapter = SyntheticAdapter("syn-vclock", 1, dwell_s=0.0)

    def gated_invoke(session):
        gate.wait(timeout=30)
        return {"output": {"echo": session.task.payload},
                "telemetry": {"drift_score": 0.0,
                              "health_status": "healthy",
                              "observation_ms": 0.0},
                "artifacts": {}, "backend_ms": 0.0}

    adapter.invoke = gated_invoke
    orch.register(adapter)

    with ControlPlaneScheduler(orch, workers=1, queue_size=8,
                               health_tick_interval_s=0.0) as sched:
        assert sched.clock is vclock       # scheduler adopts the orch clock
        blocker = sched.submit_async(_task(0))
        victim = sched.submit_async(_task(1), deadline_s=5.0)
        # 6 virtual seconds pass while the victim sits queued behind the
        # gated blocker; zero wall time elapses
        vclock.advance(6.0)
        gate.set()
        b_result, _ = blocker.result(timeout=30)
        v_result, v_trace = victim.result(timeout=30)
    assert b_result.status == "completed"
    assert v_result.status == "rejected"
    assert v_trace.error_code == ErrorCode.DEADLINE.value
    assert "deadline exceeded while queued" in (v_trace.rejected_reason or "")


def test_deadline_not_triggered_without_virtual_advance():
    """Control case: with the virtual clock untouched, the same queued
    task is NOT deadline-rejected — proving detection rides the injected
    timebase rather than wall time."""
    vclock = VirtualClock()
    orch = Orchestrator(health=False, clock=vclock)
    adapter = SyntheticAdapter("syn-vclock2", 1, dwell_s=0.0)
    orch.register(adapter)

    with ControlPlaneScheduler(orch, workers=1, queue_size=8,
                               health_tick_interval_s=0.0) as sched:
        result, _ = sched.submit_async(_task(0),
                                       deadline_s=0.001).result(timeout=30)
    assert result.status == "completed"
