"""Executable-twin unit suite: the shared confidence law, invalidation
bookkeeping, speculation + retro-invalidation, queue-saturation fallback,
and the roofline surrogate's predict-from-telemetry path."""
import time

import pytest

from repro.core import (ControlPlaneScheduler, Orchestrator, TaskRequest,
                        TwinState, TwinSyncManager)
from repro.core.telemetry import TelemetryBus, TelemetryEvent
from repro.substrates import MemristiveAdapter
from repro.substrates.tpu_pod import RooflineSurrogate


def _vector_task(**kw):
    return TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector",
                       payload=[0.2, 0.4, 0.1, 0.3], **kw)


# ---------------------------------------------------------------------------
# shared confidence law + invalidation reason (satellites 1 & 2)


def _manager_with_twin(conf: float = 0.7) -> TwinSyncManager:
    bus = TelemetryBus()
    twins = TwinSyncManager(bus)
    twins.register(TwinState("t", "r", confidence=conf))
    return twins


def test_mark_synced_and_result_event_share_one_confidence_law():
    a, b = _manager_with_twin(), _manager_with_twin()
    a.mark_synced("r", drift=0.3)
    b._on_event(TelemetryEvent("r", "result", {"drift_score": 0.3}))
    assert a.get("r").confidence == pytest.approx(b.get("r").confidence)
    assert a.get("r").drift_estimate == b.get("r").drift_estimate == 0.3


def test_drift_event_shares_the_same_law_too():
    a, b = _manager_with_twin(), _manager_with_twin()
    a.mark_synced("r", drift=0.5)
    b._on_event(TelemetryEvent("r", "drift", {"drift_score": 0.5}))
    assert a.get("r").confidence == pytest.approx(b.get("r").confidence)


def test_invalidate_records_reason_and_surfaces_in_to_dict():
    twins = _manager_with_twin()
    twins.invalidate("r", "postcondition: missing telemetry")
    tw = twins.get("r")
    assert tw.confidence == 0.0
    assert tw.invalidation_reason == "postcondition: missing telemetry"
    assert tw.to_dict()["invalidation_reason"] == \
        "postcondition: missing telemetry"
    ok, why = tw.valid(None)
    assert not ok and "postcondition: missing telemetry" in why


def test_invalidate_without_reason_still_marks_invalid():
    twins = _manager_with_twin()
    twins.invalidate("r")
    assert not twins.get("r").valid(None)[0]
    assert twins.get("r").to_dict()["invalidation_reason"] == "invalidated"


def test_passive_telemetry_cannot_clear_an_invalidation():
    twins = _manager_with_twin()
    twins.invalidate("r", "broken")
    for _ in range(50):
        twins._on_event(TelemetryEvent("r", "result", {"drift_score": 0.0}))
    tw = twins.get("r")
    assert tw.confidence > 0.5        # confidence rebuilt...
    assert not tw.valid(None)[0]      # ...but validity stays pinned False
    twins.mark_synced("r")            # explicit re-sync clears it
    assert twins.get("r").valid(None)[0]


def test_measured_agreement_clears_invalidation():
    twins = _manager_with_twin()
    twins.invalidate("r", "broken")
    twins.observe_divergence("r", divergence=0.01, tolerance=0.25)
    tw = twins.get("r")
    assert tw.invalidation_reason == ""
    # a beyond-tolerance measurement must NOT clear it
    twins.invalidate("r", "broken again")
    twins.observe_divergence("r", divergence=0.9, tolerance=0.25)
    assert not twins.get("r").valid(None)[0]


def test_per_task_min_confidence_overrides_default():
    twins = _manager_with_twin(conf=0.45)
    tw = twins.get("r")
    assert tw.valid(None)[0]                         # default floor 0.3
    assert not tw.valid(None, min_confidence=0.6)[0]
    assert tw.valid(None, min_confidence=0.2)[0]


def test_check_serve_is_atomic_snapshot():
    twins = _manager_with_twin(conf=0.8)
    tw, ok, why, conf = twins.check_serve("r")
    assert ok and conf == pytest.approx(0.8)
    twins.invalidate("r", "gone")
    tw, ok, why, conf = twins.check_serve("r")
    assert not ok and "gone" in why and conf == 0.0


# ---------------------------------------------------------------------------
# speculation: immediate twin answer, asynchronous confirmation


def test_speculate_confirms_against_real_hardware():
    orch = Orchestrator()
    orch.register(MemristiveAdapter())
    with ControlPlaneScheduler(orch, workers=2) as sched:
        spec, fut = sched.submit_speculative(
            _vector_task(twin_mode="speculate"))
        assert spec is not None
        assert spec.telemetry["served_by"] == "twin"
        assert spec.telemetry["twin_mode"] == "speculate"
        real, trace, verdict = fut.result(timeout=30)
        assert real.status == "completed"
        assert verdict["confirmed"] and not verdict["retro_invalidated"]
        assert verdict["divergence"] <= 0.25
    audit = orch.twin_exec.audit()
    assert audit["speculations"] == 1
    assert audit["speculations_confirmed"] == 1
    assert audit["twin_serves_invalid"] == 0


def test_speculation_mismatch_retro_invalidates_twin():
    orch = Orchestrator()
    orch.register(MemristiveAdapter())
    rid = "memristive-local"
    orch.twins.get(rid).surrogate.g = orch.twins.get(rid).surrogate.g + 10.0
    with ControlPlaneScheduler(orch, workers=2) as sched:
        spec, fut = sched.submit_speculative(
            _vector_task(twin_mode="speculate"))
        assert spec is not None
        real, trace, verdict = fut.result(timeout=30)
        assert real.status == "completed"
        assert verdict["retro_invalidated"]
        tw = orch.twins.get(rid)
        assert tw.invalidation_reason.startswith("speculation mismatch")
        assert not tw.valid(None)[0]
        # a subsequent speculation refuses the invalidated twin and falls
        # back to plain real execution
        spec2, fut2 = sched.submit_speculative(
            _vector_task(twin_mode="speculate"))
        assert spec2 is None
        res, _ = fut2.result(timeout=30)
        assert res.status == "completed"
    assert orch.twin_exec.audit()["retro_invalidated"] == 1
    assert orch.twin_exec.audit()["twin_serves_invalid"] == 0


# ---------------------------------------------------------------------------
# queue-saturation fallback (proactive path)


def test_saturated_queue_serves_opted_in_tasks_from_twin():
    orch = Orchestrator(twin_fallback_queue_factor=1.0)
    orch.register(MemristiveAdapter())
    rid = "memristive-local"
    # fake a deep waiting line: depth >= factor * max_concurrent (4)
    orch.bus.adjust_queue_depth(rid, +8)
    try:
        res, trace = orch.submit(_vector_task(twin_mode="fallback"))
        assert res.status == "completed"
        assert trace.served_by == "twin"
        assert "queue saturated" in res.telemetry["twin_serve_reason"]
        # tasks without the opt-in take the normal (hardware) path
        res, trace = orch.submit(_vector_task())
        assert res.status == "completed" and trace.served_by == "substrate"
    finally:
        orch.bus.adjust_queue_depth(rid, -8)


def test_deadline_lapsed_in_queue_serves_twin():
    orch = Orchestrator()
    orch.register(MemristiveAdapter())
    # a task whose deadline is already in the past when the worker picks it
    # up exercises the scheduler's saturation-endpoint twin funnel
    with ControlPlaneScheduler(orch, workers=1) as sched:
        fut = sched.submit_async(_vector_task(twin_mode="fallback"),
                                 deadline_s=-1.0)
        result, trace = fut.result(timeout=30)
        assert result.status == "completed"
        assert trace.served_by == "twin"
        assert "deadline exceeded" in result.telemetry["twin_serve_reason"]
        fut = sched.submit_async(_vector_task(), deadline_s=-1.0)
        result, trace = fut.result(timeout=30)
        assert result.status == "rejected"


# ---------------------------------------------------------------------------
# roofline surrogate (TPU pod twin) — predict-from-telemetry unit path


def test_roofline_surrogate_predicts_from_observations():
    sur = RooflineSurrogate({"step_time_lb_s": 0.05}, steps_per_invoke=3,
                            batch=4, seq=64)
    task = TaskRequest(function="train", input_modality="tensor_shards",
                       output_modality="tensor_shards", payload={"steps": 3})
    # cold: answers from the roofline lower bound
    raw = sur.simulate(task)
    assert raw["telemetry"]["step_ms"] == pytest.approx(50.0)
    # after observing real telemetry the prediction tracks the median
    sur.observe(task, {"output": {"step": 6, "loss": 2.5},
                       "telemetry": {"step_ms": 48.0, "grad_norm": 1.0}})
    raw = sur.simulate(task)
    assert raw["output"]["step"] == 9
    assert raw["telemetry"]["step_ms"] == pytest.approx(48.0)
    div = sur.divergence({"step": 9, "loss": 2.49}, raw["output"])
    assert div <= sur.tolerance


def test_roofline_surrogate_not_ready_without_record_or_telemetry():
    from repro.core import TwinNotReady

    sur = RooflineSurrogate(None, steps_per_invoke=3, batch=4, seq=64)
    with pytest.raises(TwinNotReady):
        sur.simulate(TaskRequest(function="train",
                                 input_modality="tensor_shards",
                                 output_modality="tensor_shards"))
