"""Capability-model tests (paper Table I + RQ1 shared-key ratio)."""
import pytest

from repro.core import shared_key_ratio
from repro.core.descriptors import CapabilityDescriptor
from repro.substrates import (ChemicalAdapter, CorticalLabsAdapter,
                              MemristiveAdapter, WetwareAdapter)
from repro.substrates.http_fast import HTTPFastAdapter

ADAPTERS = [ChemicalAdapter(), WetwareAdapter(), MemristiveAdapter(),
            HTTPFastAdapter("http://127.0.0.1:1"), CorticalLabsAdapter()]


def test_descriptor_shared_key_ratio_is_one():
    """RQ1: the same top-level descriptor structure across all 5 backends."""
    dicts = [a.descriptor().to_dict() for a in ADAPTERS]
    assert shared_key_ratio(dicts) == 1.0
    cap_dicts = [d["capability"] for d in dicts]
    assert shared_key_ratio(cap_dicts) == 1.0


def test_descriptor_covers_table_one_categories():
    d = ChemicalAdapter().descriptor().to_dict()
    cap = d["capability"]
    # Table I: identity, signal, timing, lifecycle, programmability,
    # observability, policy/tenancy
    assert d["substrate_class"] and d["adapter_type"] and d["location"]
    assert d["twin_binding"]
    for section in ("input_signal", "output_signal", "timing", "lifecycle",
                    "programmability", "observability", "policy"):
        assert section in cap, section
    assert cap["timing"]["latency_regime"] in ("slow_seconds", "fast_ms",
                                               "sub_ms")
    assert cap["lifecycle"]["reset_modes"]
    assert cap["observability"]["telemetry_fields"]


def test_substrate_differences_stay_explicit():
    """The control plane must NOT flatten substrate differences (paper §I)."""
    chem = ChemicalAdapter().descriptor()
    wet = WetwareAdapter().descriptor()
    mem = MemristiveAdapter().descriptor()
    assert chem.capability.input_signal.modality == "concentration"
    assert wet.capability.input_signal.modality == "spikes"
    assert mem.capability.input_signal.modality == "vector"
    assert chem.capability.timing.latency_regime == "slow_seconds"
    assert wet.capability.timing.latency_regime == "fast_ms"
    assert chem.capability.lifecycle.reset_modes == ("flush", "recharge")
    assert "rest" in wet.capability.lifecycle.reset_modes
    assert wet.capability.policy.requires_supervision
    assert not mem.capability.policy.requires_supervision


def test_shared_key_ratio_detects_divergence():
    assert shared_key_ratio([{"a": 1, "b": 2}, {"a": 1}]) == 0.5
    assert shared_key_ratio([]) == 0.0
