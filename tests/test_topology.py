"""Hierarchical federation: multi-hop chains, budgets, cycles, recovery.

The acceptance demo for the topology refactor: a device → edge → fog →
cloud chain of planes where

- tasks forward end-to-end with one identity and a complete hop route;
- ``hop_budget`` / ``deadline_budget_ms`` exhaustion rejects with the
  structured ``DEADLINE`` code exactly at the hop the budget predicts;
- federating a plane that can transitively reach its would-be parent is
  refused with ``FEDERATION_CYCLE``;
- killing a mid-chain plane trips the parent's breaker through the
  telemetry STREAM (no polling-interval lag), opted-in traffic twin-serves
  with zero invalid serves, and the descriptor change feed re-admits the
  plane on recovery without any ``discover()`` re-fetch.
"""
import time

import pytest

from repro.core import (ControlPlaneError, ErrorCode, Orchestrator,
                        PlaneTopology, TaskRequest, budget_admissible,
                        forward_task)
from repro.core.health import BreakerState
from repro.core.topology import DEFAULT_HOP_BUDGET, HOP_WIRE_MARGIN_MS
from repro.gateway import ControlPlaneGateway
from repro.substrates import MemristiveAdapter, federate


def _task(**kw):
    return TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector", payload=[0.1, 0.2, 0.3, 0.4],
                       **kw)


# ---------------------------------------------------------------------------
# topology unit layer


def test_reachable_is_transitive_closure():
    top = PlaneTopology("cloud")
    top.add_child("fog-1", {"fog-1", "edge-1", "device-1"})
    top.add_child("lab-1", {"lab-1"})
    assert top.reachable() == {top.plane_id, "fog-1", "edge-1", "device-1",
                               "lab-1"}


def test_direct_cycle_refused():
    top = PlaneTopology("cloud")
    with pytest.raises(ControlPlaneError) as ei:
        top.add_child("child-x", {"child-x", top.plane_id})
    assert ei.value.code is ErrorCode.FEDERATION_CYCLE


def test_transitive_cycle_refused():
    """A reaches B reaches C; registering A into C must refuse."""
    a, b, c = PlaneTopology("a"), PlaneTopology("b"), PlaneTopology("c")
    b.add_child(c.plane_id, c.reachable())
    a.add_child(b.plane_id, b.reachable())
    with pytest.raises(ControlPlaneError) as ei:
        c.add_child(a.plane_id, a.reachable())
    assert ei.value.code is ErrorCode.FEDERATION_CYCLE


def test_forward_task_budget_math():
    t = _task(latency_budget_ms=20.0)
    fwd = forward_task(t, "plane-x")
    # first forward seeds the default hop budget and converts the latency
    # budget into an explicit decremented deadline budget
    assert fwd.hop_budget == DEFAULT_HOP_BUDGET - 1
    assert fwd.deadline_budget_ms == 20.0 - HOP_WIRE_MARGIN_MS
    assert fwd.route == ("plane-x",)
    assert fwd.task_id == t.task_id          # one identity across hops
    fwd2 = forward_task(fwd, "plane-y")
    assert fwd2.hop_budget == DEFAULT_HOP_BUDGET - 2
    assert fwd2.deadline_budget_ms == 20.0 - 2 * HOP_WIRE_MARGIN_MS
    assert fwd2.route == ("plane-x", "plane-y")


def test_forward_task_refuses_exhausted_budgets():
    with pytest.raises(ControlPlaneError) as ei:
        forward_task(_task(hop_budget=0), "plane-x")
    assert ei.value.code is ErrorCode.DEADLINE
    with pytest.raises(ControlPlaneError) as ei:
        forward_task(_task(deadline_budget_ms=HOP_WIRE_MARGIN_MS), "plane-x")
    assert ei.value.code is ErrorCode.DEADLINE


def test_budget_admissible_unbudgeted_task_passes():
    ok, _ = budget_admissible(_task())
    assert ok
    ok, why = budget_admissible(_task(hop_budget=0))
    assert not ok and "hop budget" in why


def test_wire_round_trip_preserves_budgets():
    t = _task(hop_budget=3, deadline_budget_ms=42.5,
              route=("plane-a", "plane-b"))
    back = TaskRequest.from_wire(t.to_wire())
    assert back.hop_budget == 3
    assert back.deadline_budget_ms == 42.5
    assert back.route == ("plane-a", "plane-b")


# ---------------------------------------------------------------------------
# the 4-plane chain


@pytest.fixture()
def chain():
    """device → edge → fog → cloud; yields (planes, gateways, adapters)."""
    planes, gateways, adapters = {}, {}, {}
    planes["device"] = Orchestrator()
    planes["device"].register(MemristiveAdapter("device-xbar"))
    gateways["device"] = ControlPlaneGateway(planes["device"],
                                             plane="device").start()
    for child, parent in (("device", "edge"), ("edge", "fog"),
                          ("fog", "cloud")):
        planes[parent] = Orchestrator(health=dict(
            cooldown_s=0.4,
            thresholds={"consecutive_failures_to_open": 2}))
        adapters[parent] = federate(planes[parent], gateways[child].url)
        if parent != "cloud":
            gateways[parent] = ControlPlaneGateway(planes[parent],
                                                   plane=parent).start()
    try:
        yield planes, gateways, adapters
    finally:
        for gw in gateways.values():
            gw.stop()
        for a in adapters.values():
            a.close()


def test_chain_forwards_end_to_end(chain):
    planes, _, adapters = chain
    task = _task(required_telemetry=("execution_ms",))
    res, trace = planes["cloud"].submit(task)
    assert res.status == "completed"
    assert trace.selected == adapters["cloud"].resource_id
    # the task reached the device plane's physical substrate
    assert res.telemetry["remote_resource_id"] == adapters["fog"].resource_id
    route = res.telemetry["hop_route"]
    assert route == [planes["cloud"].topology.plane_id,
                     planes["fog"].topology.plane_id,
                     planes["edge"].topology.plane_id]
    # identity survives all three hops: the innermost trace names our task
    assert res.artifacts["remote_trace"]["task_id"] == task.task_id


@pytest.mark.parametrize("hops,expect", [(0, "rejected"), (1, "rejected"),
                                         (2, "rejected"), (3, "completed")])
def test_hop_budget_exhausts_exactly_where_predicted(chain, hops, expect):
    """Reaching the device substrate needs exactly 3 forwards; any smaller
    hop budget must reject with the structured DEADLINE code."""
    planes, _, _ = chain
    res, trace = planes["cloud"].submit(_task(hop_budget=hops))
    assert res.status == expect
    if expect == "rejected":
        assert trace.error_code == ErrorCode.DEADLINE.value


def test_deadline_budget_exhausts_exactly_where_predicted(chain):
    """Each hop costs HOP_WIRE_MARGIN_MS of deadline budget and a plane
    refuses to forward once the remaining budget is <= one margin, so the
    minimum completing budget is 3 margins + epsilon."""
    planes, _, _ = chain
    short = 3 * HOP_WIRE_MARGIN_MS          # absorbs only 2 hops
    res, trace = planes["cloud"].submit(_task(deadline_budget_ms=short))
    assert res.status == "rejected"
    assert trace.error_code == ErrorCode.DEADLINE.value
    enough = 3 * HOP_WIRE_MARGIN_MS + 200.0
    res, _ = planes["cloud"].submit(_task(deadline_budget_ms=enough))
    assert res.status == "completed"


def test_federation_cycle_refused_end_to_end(chain):
    """The fog plane transitively reaches edge and device; registering it
    back into the DEVICE plane would let forwarded tasks come home."""
    planes, gateways, _ = chain
    with pytest.raises(ControlPlaneError) as ei:
        federate(planes["device"], gateways["fog"].url)
    assert ei.value.code is ErrorCode.FEDERATION_CYCLE
    # the refused child never made it into the registry
    assert all("plane-fog" not in d.resource_id
               for d in planes["device"].registry.all())


def test_self_federation_refused():
    orch = Orchestrator()
    orch.register(MemristiveAdapter("self-xbar"))
    gw = ControlPlaneGateway(orch, plane="selfie").start()
    try:
        with pytest.raises(ControlPlaneError) as ei:
            federate(orch, gw.url)
        assert ei.value.code is ErrorCode.FEDERATION_CYCLE
    finally:
        gw.stop()


# ---------------------------------------------------------------------------
# mid-chain failure + stream-driven recovery


def _await(predicate, timeout_s: float, interval_s: float = 0.02) -> bool:
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(interval_s)
    return False


def test_mid_chain_kill_trips_breaker_via_stream_and_feed_readmits():
    """Kill the EDGE plane of a device→edge→fog chain: the fog-side breaker
    must open from the broken stream (within ~2 heartbeats — no long-poll
    lag), opted-in traffic twin-serves with zero invalid serves, and after
    the edge gateway returns ON THE SAME PORT the change feed re-admits the
    plane without any discover() re-fetch."""
    device = Orchestrator()
    device.register(MemristiveAdapter("device-xbar"))
    gw_device = ControlPlaneGateway(device, plane="device").start()
    edge = Orchestrator()
    a_edge = federate(edge, gw_device.url)
    gw_edge = ControlPlaneGateway(edge, plane="edge").start()
    edge_port = gw_edge.port
    fog = Orchestrator(health=dict(
        cooldown_s=0.3, thresholds={"consecutive_failures_to_open": 2}))
    a_fog = federate(fog, gw_edge.url)
    rid = a_fog.resource_id
    gw_edge2 = None
    try:
        # warm the fog-side twin of the edge plane
        for _ in range(6):
            res, _ = fog.submit(_task(twin_mode="shadow"))
            assert res.status == "completed"
        discovers = []
        a_fog.client.discover = lambda *a, **kw: discovers.append(1)  # spy

        # -- kill the mid-chain plane ------------------------------------
        t_kill = time.monotonic()
        gw_edge.stop()
        assert _await(lambda: fog.health.state(rid) is BreakerState.OPEN,
                      timeout_s=4.0), "breaker must trip via the stream"
        trip_s = time.monotonic() - t_kill
        # stream detection, not poll-interval luck: well under the old
        # long-poll worst case and within ~2 follower heartbeats + margin
        assert trip_s < 4.0

        # opted-in traffic twin-serves while the plane is quarantined
        served = []
        for _ in range(6):
            res, trace = fog.submit(_task(twin_mode="fallback"))
            assert res.status == "completed"
            if trace.served_by == "twin":
                served.append(res)
        assert served, "twin must serve while the plane is down"
        audit = fog.twin_exec.audit()
        assert audit["twin_serves_invalid"] == 0

        # -- recovery: same port, same orchestrator ----------------------
        gw_edge2 = ControlPlaneGateway(edge, port=edge_port,
                                       plane="edge").start()
        assert _await(lambda: a_fog._stream_connects >= 2, timeout_s=6.0), \
            "follower must resubscribe to the recovered plane"
        # breaker walks open → probation → healthy on real forwarded work
        deadline = time.monotonic() + 10.0
        reai = None
        while time.monotonic() < deadline:
            res, trace = fog.submit(_task())
            if res.status == "completed" and trace.served_by == "substrate":
                reai = res
                break
            time.sleep(0.1)
        assert reai is not None, "plane must be re-admitted after recovery"
        # edge placed it on ITS device-plane adapter: real hardware again
        assert reai.telemetry["remote_resource_id"] == a_edge.resource_id
        # the re-admission used the change feed + stream, never a re-fetch
        assert discovers == []
    finally:
        for gw in (gw_device, gw_edge2):
            if gw is not None:
                gw.stop()
        a_edge.close()
        a_fog.close()


def test_descriptor_change_feed_reaggregates_parent_view():
    """Registering/unregistering a member on the child plane must reshape
    the parent's aggregated descriptor live, without re-federation."""
    child = Orchestrator()
    child.register(MemristiveAdapter("xbar-a"))
    gw = ControlPlaneGateway(child, plane="lab").start()
    parent = Orchestrator()
    adapter = federate(parent, gw.url)
    rid = adapter.resource_id
    try:
        assert parent.registry.get(rid).capability.policy.max_concurrent == 4
        epoch0 = parent.registry.epoch
        child.register(MemristiveAdapter("xbar-b"))     # fleet grows
        assert _await(
            lambda: parent.registry.get(rid) is not None
            and parent.registry.get(rid).capability.policy.max_concurrent == 8,
            timeout_s=4.0), "parent aggregate must absorb the new member"
        assert parent.registry.epoch > epoch0
        child.unregister("xbar-b")                      # fleet shrinks
        assert _await(
            lambda: parent.registry.get(rid).capability.policy.max_concurrent
            == 4, timeout_s=4.0), "parent aggregate must drop the member"
        # tasks still route end-to-end through the updated aggregate
        res, _ = parent.submit(_task())
        assert res.status == "completed"
        assert res.telemetry["remote_resource_id"] == "xbar-a"
    finally:
        gw.stop()
        adapter.close()
