"""Property-based tests (hypothesis) for the recovery subsystem.

1. Breaker legality: NO sequence of telemetry events, attempt outcomes,
   clock advances and probe ticks may ever produce an illegal breaker
   transition, and transitions must chain (each src == previous dst).
2. PolicyManager slot-audit invariants: under any acquire/release
   interleaving (concurrency slots and probation probe slots),
   ``outstanding`` matches the model and ``fully_released`` holds exactly
   when everything acquired has been returned.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.policy import PolicyManager
from tests.test_health_manager import (assert_history_legal,
                                       run_breaker_sequence)
from tests.test_scheduler_concurrency import SyntheticAdapter

breaker_op = st.one_of(
    st.tuples(st.just("outcome"), st.booleans()),
    st.tuples(st.just("drift"), st.floats(0.0, 1.0)),
    st.tuples(st.just("advance"), st.floats(0.0, 2.0)),
    st.tuples(st.just("tick")),
)


@settings(max_examples=60, deadline=None)
@given(ops=st.lists(breaker_op, max_size=80),
       cooldown=st.floats(0.1, 2.0),
       probes=st.integers(1, 4))
def test_breaker_transitions_always_legal(ops, cooldown, probes):
    """Arbitrary telemetry/outcome/clock sequences: the state machine never
    leaves the legal transition graph and never leaks a probe slot."""
    h, history = run_breaker_sequence(ops, cooldown_s=cooldown,
                                      probes_to_close=probes)
    assert_history_legal(history)
    audit = h.audit()
    assert audit["probes_outstanding"] == 0
    assert audit["started_while_open"] == 0


slot_op = st.one_of(
    st.tuples(st.just("acquire")),
    st.tuples(st.just("release")),
    st.tuples(st.just("acquire_probe"), st.integers(1, 3)),
    st.tuples(st.just("release_probe")),
)


@settings(max_examples=60, deadline=None)
@given(ops=st.lists(slot_op, max_size=60), max_concurrent=st.integers(1, 4))
def test_policy_slot_audit_invariants(ops, max_concurrent):
    """outstanding/fully_released track exactly the acquired-minus-released
    slots under any interleaving; acquisition respects max_concurrent and
    probe acquisition respects the probe budget."""
    pm = PolicyManager()
    desc = SyntheticAdapter("res", max_concurrent).descriptor()
    held = 0
    probes = 0
    for op in ops:
        if op[0] == "acquire":
            got = pm.acquire(desc, timeout_s=0.0)
            assert got == (held < max_concurrent)
            held += got
        elif op[0] == "release" and held > 0:
            pm.release(desc)
            held -= 1
        elif op[0] == "acquire_probe":
            budget = op[1]
            got = pm.acquire_probe("res", budget)
            assert got == (probes < budget)
            probes += got
        elif op[0] == "release_probe" and probes > 0:
            pm.release_probe("res")
            probes -= 1
        # audit matches the model at EVERY step, not just at the end
        assert pm.outstanding().get("res", 0) == held
        assert pm.probes_held("res") == probes
        assert pm.fully_released() == (held == 0 and probes == 0)
    for _ in range(held):
        pm.release(desc)
    for _ in range(probes):
        pm.release_probe("res")
    assert pm.fully_released()
