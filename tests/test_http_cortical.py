"""Externalized HTTP path (RQ3) + Cortical-Labs API path (RQ1/RQ3)."""
import numpy as np
import pytest

from repro.core import Orchestrator, TaskRequest
from repro.substrates import standard_testbed
from repro.substrates.cortical import CLClient, CLSimulator


def test_http_backend_roundtrip(orchestrator):
    res, _ = orchestrator.submit(TaskRequest(
        function="inference", input_modality="vector",
        output_modality="vector", backend_preference="fast-external",
        payload=[0.25, 0.25, 0.25, 0.25],
        required_telemetry=("execution_ms", "transport_ms")))
    assert res.status == "completed"
    assert res.resource_id == "fast-external"
    assert len(res.output["vector"]) == 4
    # transport cost is real (HTTP over loopback) and separated from backend
    assert res.telemetry["transport_ms"] > 0.0
    assert res.timing_ms["backend_ms"] < res.timing_ms["total_ms"]


def test_http_rtt_structure(orchestrator):
    """RTT = backend + transport/boundary cost (paper RQ3 decomposition)."""
    adapter = orchestrator.registry.adapter("fast-external")
    samples = []
    for _ in range(5):
        res, _ = orchestrator.submit(TaskRequest(
            function="inference", input_modality="vector",
            output_modality="vector", backend_preference="fast-external",
            payload=[0.1, 0.9, 0.1, 0.9]))
        samples.append((res.timing_ms["backend_ms"],
                        res.timing_ms["total_ms"]))
    for backend_ms, total_ms in samples:
        assert total_ms >= backend_ms


class TestCorticalPath:
    def test_cl_simulator_session_api(self):
        sim = CLSimulator()
        cultures = sim.list_cultures()
        assert cultures and cultures[0]["culture_id"] == "culture-A"
        sid = sim.open_session("culture-A")
        sim.upload_stim_program(sid, {"pattern": [1, 0, 1], "amplitude": 1.0})
        rec = sim.stim_and_record(sid, window_ms=120.0)
        sim.close_session(sid)
        assert rec["recording_id"].startswith("rec-")
        assert len(rec["spike_counts"]) == 64
        assert rec["observation_ms"] == 120.0

    def test_stim_before_program_fails(self):
        sim = CLSimulator()
        sid = sim.open_session("culture-A")
        with pytest.raises(RuntimeError):
            sim.stim_and_record(sid)

    def test_three_directed_screening_runs(self, orchestrator):
        """Paper §VIII-A: three directed runs, no fallback, structured
        recording artifact, health exposed before and after."""
        snap_before = orchestrator.bus.snapshot("cortical-labs-backend")
        assert snap_before is not None
        for i in range(3):
            res, trace = orchestrator.submit(TaskRequest(
                function="screening", input_modality="spikes",
                output_modality="spikes",
                backend_preference="cortical-labs-backend",
                payload={"pattern": [1, 0, 1, 1], "amplitude": 1.0},
                required_telemetry=("culture_health", "firing_rate_hz")))
            assert res.status == "completed", res.telemetry
            assert res.resource_id == "cortical-labs-backend"
            assert not trace.fallback_used
            rec = res.artifacts["recording"]
            assert rec["recording_id"].startswith("rec-")
            assert rec["format"] == "spike_counts/v1"
            # the paper's timing-structure point: session handling dominates
            # the short observation cycle
            assert res.telemetry["session_ms"] > res.telemetry["observation_ms"]

    def test_cl_backend_falls_back_to_synthetic_wetware(self, orchestrator):
        """Paper §IV-D: the same request can fall back to a compatible
        synthetic wetware backend when the external path fails."""
        orchestrator.registry.adapter("cortical-labs-backend").inject_fault(
            "prepare_failure")
        res, trace = orchestrator.submit(TaskRequest(
            function="screening", input_modality="spikes",
            output_modality="spikes",
            backend_preference="cortical-labs-backend",
            payload={"pattern": [1, 1, 0, 1]},
            required_telemetry=("firing_rate_hz",)))
        assert res.status == "completed"
        assert res.resource_id == "wetware-synthetic"
        assert trace.fallback_used
