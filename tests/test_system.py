"""End-to-end behaviour tests for the phys-MCP system (paper workflows)."""
import numpy as np

from repro.core import Orchestrator, TaskRequest
from repro.core.invocation import RESULT_KEYS


def test_capability_driven_workflow(orchestrator):
    """Paper §IV-D: discover → submit → normalized result."""
    found = orchestrator.discover(input_modality="spikes", repeated=True)
    assert {d.resource_id for d in found} >= {"wetware-synthetic",
                                              "cortical-labs-backend"}
    res, trace = orchestrator.submit(TaskRequest(
        function="screening", input_modality="spikes",
        output_modality="spikes", payload={"pattern": [1, 0, 1, 1]},
        required_telemetry=("firing_rate_hz",)))
    assert res.status == "completed"
    assert set(res.to_dict().keys()) == set(RESULT_KEYS)
    assert trace.selected == res.resource_id


def test_directed_workflow(orchestrator):
    res, trace = orchestrator.submit(TaskRequest(
        function="assay", input_modality="concentration",
        output_modality="concentration",
        backend_preference="chemical-ode",
        payload={"concentrations": [0.1, 0.8, 0.1, 0.1]}))
    assert res.status == "completed"
    assert res.resource_id == "chemical-ode"
    assert res.output["winner"] == 1


def test_orchestration_trace_is_explainable(orchestrator):
    res, trace = orchestrator.submit(TaskRequest(
        function="inference", input_modality="vector",
        output_modality="vector", payload=[0.2, 0.2, 0.2, 0.2]))
    assert trace.attempts and trace.attempts[0]["terms"]
    assert trace.control_overhead_ms >= 0.0


def test_control_overhead_is_small(orchestrator):
    """RQ3: absolute control-path overhead below ~10 ms per invocation
    (paper reports <1 ms; CI boxes are slower, keep headroom)."""
    overheads = []
    for _ in range(10):
        res, trace = orchestrator.submit(TaskRequest(
            function="inference", input_modality="vector",
            output_modality="vector", payload=[0.4, 0.1, 0.1, 0.4]))
        overheads.append(trace.control_overhead_ms)
    assert np.median(overheads) < 10.0, overheads


def test_tpu_fleet_joins_the_same_control_plane(orchestrator):
    """DESIGN.md §2: pod slices are substrates like any other."""
    from repro.substrates.tpu_pod import TpuPodSubstrate
    sub = TpuPodSubstrate("rwkv6-7b", batch=2, seq=16)
    orchestrator.register(sub)
    res, _ = orchestrator.submit(TaskRequest(
        function="train_step", input_modality="tensor_shards",
        output_modality="tensor_shards", payload={"steps": 1},
        required_telemetry=("loss", "step_ms")))
    assert res.status == "completed"
    assert res.resource_id == sub.resource_id
    assert np.isfinite(res.telemetry["loss"])
    twin = orchestrator.twins.get(sub.resource_id)
    assert twin.kind == "roofline"
