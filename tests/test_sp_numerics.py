"""Numerical equivalence of the explicit shard_map SP/EP paths.

The hillclimb replaced pjit-propagated attention/FFN/MoE with hand-written
shard_map blocks (sp_attention, sp_ffn, sp_moe, sp_block). These tests
prove the distributed graphs compute the SAME loss and gradients as the
single-device model — run in a subprocess so an 8-device host platform can
be configured before JAX initializes.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow    # heavy suite: excluded from make test-fast

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, "src")
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding

    from repro.configs import get_config, reduced
    from repro.distributed.ctx import sharding_ctx
    from repro.distributed.sharding import RECIPES, param_shardings
    from repro.models import loss_fn, model_specs
    from repro.models.common import init_params

    arch = sys.argv[1]
    overrides = dict(d_model=64, num_layers=2, vocab_size=128, attn_chunk=16)
    if arch != "rwkv6-7b":   # rwkv head layout is fixed by its own config
        overrides.update(num_heads=8, num_kv_heads={kv}, head_dim=16)
    cfg = reduced(get_config(arch), **overrides)
    params = init_params(model_specs(cfg), seed=3)
    rng = np.random.default_rng(0)
    B, S = 4, 32
    batch = {{"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
              "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}}
    if cfg.family == "vision":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_image_tokens, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_frames, cfg.d_model)), jnp.float32)

    def grad_loss(p, b):
        (l, _), g = jax.value_and_grad(lambda q: loss_fn(cfg, q, b),
                                       has_aux=True)(p)
        return l, g

    # reference: single device, no sharding ctx
    l_ref, g_ref = jax.jit(grad_loss)(params, batch)

    # distributed: 2x4 mesh (data x model), SP/EP shard_map paths active
    mesh = jax.make_mesh((2, 4), ("data", "model"), devices=jax.devices(),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    recipe = RECIPES["baseline"]
    shardings = param_shardings(model_specs(cfg), recipe, mesh)
    p_sh = jax.device_put(params, shardings)
    b_sh = jax.device_put(batch, NamedSharding(mesh, P("data")))
    with mesh, sharding_ctx(mesh, recipe):
        l_sp, g_sp = jax.jit(grad_loss)(p_sh, b_sh)

    dl = abs(float(l_ref) - float(l_sp))
    worst = 0.0
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_sp)):
        an = np.asarray(a, np.float32); bn = np.asarray(b, np.float32)
        scale = max(np.abs(an).max(), 1e-3)
        worst = max(worst, float(np.abs(an - bn).max() / scale))
    print(json.dumps({{"loss_ref": float(l_ref), "loss_sp": float(l_sp),
                       "dloss": dl, "worst_grad_rel": worst}}))
""")


@pytest.mark.parametrize("arch,kv", [
    ("internlm2-20b", 4),        # heads-sharded GQA variant (8H over 4-way TP)
    ("qwen2.5-32b", 2),          # seq-sharded variant lives via non-div kv? (8%4=0 -> heads)
    ("deepseek-v2-236b", 8),     # MLA whole-block + EP MoE (4 experts over 4)
    ("recurrentgemma-9b", 1),    # RG-LRU + local attn hybrid
    ("rwkv6-7b", 8),             # rwkv constraints path
])
def test_sp_paths_match_single_device(arch, kv):
    script = SCRIPT.format(kv=kv)
    out = subprocess.run([sys.executable, "-c", script, arch],
                         capture_output=True, text=True, cwd=ROOT,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["dloss"] < 2e-4, res
    assert res["worst_grad_rel"] < 5e-3, res
