"""Concurrent control plane: scheduler stress + thread-safety invariants.

Stress shape: ~100 tasks submitted from 8 producer threads against a
testbed of synthetic substrates with max_concurrent 1..4.  Invariants:
no lost or duplicated session ids, no semaphore leaks (PolicyManager fully
released after drain), every result status in the normalized set, and the
lifecycle state machine lands in a legal quiescent state.
"""
import threading
import time

import pytest

from repro.core import (ControlPlaneScheduler, Orchestrator, TaskRequest)
from repro.core.descriptors import (CapabilityDescriptor, LifecycleSemantics,
                                    Observability, PolicyConstraints,
                                    ResourceDescriptor, SignalSpec,
                                    TimingSemantics)
from repro.core.lifecycle import LifecycleState
from repro.core.scheduler import SchedulerClosed
from repro.core.telemetry import RuntimeSnapshot
from repro.substrates.base import SubstrateAdapter

NORMALIZED_STATUSES = {"completed", "rejected", "failed", "invalidated"}


class SyntheticAdapter(SubstrateAdapter):
    """Tiny in-process substrate with a configurable concurrency budget and
    dwell, plus an invariant check: concurrent invocations must never exceed
    max_concurrent (that would mean PolicyManager admission leaked)."""

    def __init__(self, rid: str, max_concurrent: int, dwell_s: float = 0.002,
                 needs_reset_every: int = 0):
        super().__init__()
        self.resource_id = rid
        self.max_concurrent = max_concurrent
        self.dwell_s = dwell_s
        self.needs_reset_every = needs_reset_every
        self._mu = threading.Lock()
        self._in_flight = 0
        self.peak_in_flight = 0
        self.invocations = 0
        self.resets = 0

    def descriptor(self) -> ResourceDescriptor:
        cap = CapabilityDescriptor(
            functions=("inference",),
            input_signal=SignalSpec("vector"),
            output_signal=SignalSpec("vector"),
            timing=TimingSemantics("fast_ms", 5.0, observation_window_ms=5.0),
            lifecycle=LifecycleSemantics(recovery_modes=("soft",)),
            programmability="fixed",
            observability=Observability(output_channels=("vector_out",),
                                        telemetry_fields=("drift_score",)),
            policy=PolicyConstraints(exclusive=self.max_concurrent == 1,
                                     max_concurrent=self.max_concurrent),
        )
        return ResourceDescriptor(
            resource_id=self.resource_id, substrate_class="synthetic",
            adapter_type="in_process", location="edge", twin_binding=None,
            capability=cap)

    def prepare(self, session) -> None:
        self._check_prepare_fault()

    def invoke(self, session):
        with self._mu:
            self._in_flight += 1
            self.invocations += 1
            self.peak_in_flight = max(self.peak_in_flight, self._in_flight)
            n = self.invocations
        time.sleep(self.dwell_s)
        with self._mu:
            self._in_flight -= 1
        needs_reset = (self.needs_reset_every > 0
                       and n % self.needs_reset_every == 0)
        return {"output": {"echo": session.task.payload},
                "telemetry": {"drift_score": 0.0, "health_status": "healthy",
                              "observation_ms": self.dwell_s * 1e3},
                "artifacts": {}, "backend_ms": self.dwell_s * 1e3,
                "needs_reset": needs_reset}

    def reset(self, mode: str = "soft") -> None:
        self.resets += 1

    def snapshot(self):
        return RuntimeSnapshot(self.resource_id)


def build_orchestrator():
    orch = Orchestrator()
    adapters = [SyntheticAdapter("syn-c1", 1, needs_reset_every=7),
                SyntheticAdapter("syn-c2", 2),
                SyntheticAdapter("syn-c4", 4)]
    for a in adapters:
        orch.register(a)
    return orch, adapters


def _mk_task(i: int) -> TaskRequest:
    return TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector", payload=[i])


def test_stress_8_threads_100_tasks_no_lost_or_duplicated_sessions():
    orch, adapters = build_orchestrator()
    results = []
    res_lock = threading.Lock()

    with ControlPlaneScheduler(orch, workers=12, queue_size=64) as sched:
        def producer(k):
            futs = [sched.submit_async(_mk_task(k * 100 + i))
                    for i in range(13)]
            got = [f.result(timeout=60) for f in futs]
            with res_lock:
                results.extend(got)

        threads = [threading.Thread(target=producer, args=(k,))
                   for k in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert sched.drain(timeout=60)

    assert len(results) == 8 * 13  # 104 tasks, none lost
    # every result status normalized
    assert {r.status for r, _ in results} <= NORMALIZED_STATUSES
    # all completed (blocking admission: contention must NOT surface as
    # spurious "concurrency limit" rejections)
    assert all(r.status == "completed" for r, _ in results), \
        {r.status for r, _ in results}
    # no duplicated session ids
    sids = [r.session_id for r, _ in results]
    assert len(set(sids)) == len(sids)
    # no semaphore leaks after drain
    assert orch.policy.fully_released(), orch.policy.outstanding()
    # concurrency budgets were respected at the adapter level
    for a in adapters:
        assert a.peak_in_flight <= a.max_concurrent, \
            (a.resource_id, a.peak_in_flight)
    # lifecycle quiesced into a legal terminal state per substrate
    for a in adapters:
        assert orch.lifecycle.state(a.resource_id) in (
            LifecycleState.READY, LifecycleState.NEEDS_RESET,
            LifecycleState.UNINITIALIZED)
        assert orch.lifecycle.active_sessions(a.resource_id) == 0
    # the tasks actually spread across the fleet rather than serializing
    assert sum(a.invocations for a in adapters) == 8 * 13


def test_max_concurrent_1_substrate_serializes_but_loses_nothing():
    orch = Orchestrator()
    a = SyntheticAdapter("syn-solo", 1, dwell_s=0.001)
    orch.register(a)
    with ControlPlaneScheduler(orch, workers=8) as sched:
        results = sched.submit_many([_mk_task(i) for i in range(40)])
    assert all(r.status == "completed" for r, _ in results)
    assert a.peak_in_flight == 1
    assert orch.policy.fully_released()


def test_needs_reset_recovery_is_safe_under_concurrency():
    orch = Orchestrator()
    a = SyntheticAdapter("syn-reset", 2, dwell_s=0.001, needs_reset_every=3)
    orch.register(a)
    with ControlPlaneScheduler(orch, workers=6) as sched:
        results = sched.submit_many([_mk_task(i) for i in range(30)])
    assert all(r.status == "completed" for r, _ in results)
    # a reset requested while sessions overlapped is deferred to last-out:
    # the substrate either already recovered mid-run, or is parked in
    # NEEDS_RESET now and MUST recover before serving the next task
    if a.resets == 0:
        assert orch.lifecycle.state("syn-reset") == LifecycleState.NEEDS_RESET
        res, _ = orch.submit(_mk_task(99))
        assert res.status == "completed"
        assert a.resets >= 1       # recovery ran before the new session
    assert orch.policy.fully_released()


def test_submit_async_returns_future_and_drain_quiesces():
    orch, _ = build_orchestrator()
    sched = ControlPlaneScheduler(orch, workers=4)
    try:
        fut = sched.submit_async(_mk_task(1))
        res, trace = fut.result(timeout=30)
        assert res.status == "completed"
        assert trace.selected == res.resource_id
        assert sched.drain(timeout=10)
        assert sched.pending == 0
    finally:
        sched.shutdown()


def test_scheduler_rejects_after_shutdown():
    orch, _ = build_orchestrator()
    sched = ControlPlaneScheduler(orch, workers=2)
    sched.start()
    sched.shutdown()
    with pytest.raises(SchedulerClosed):
        sched.submit_async(_mk_task(1))


def test_queued_deadline_expiry_rejects_without_touching_substrate():
    orch = Orchestrator()
    a = SyntheticAdapter("syn-slow", 1, dwell_s=0.05)
    orch.register(a)
    with ControlPlaneScheduler(orch, workers=1) as sched:
        futs = [sched.submit_async(_mk_task(i), deadline_s=0.01)
                for i in range(6)]
        results = [f.result(timeout=30) for f in futs]
    statuses = [r.status for r, _ in results]
    assert statuses[0] == "completed"
    assert "rejected" in statuses          # later tasks lapsed while queued
    assert {s for s in statuses} <= {"completed", "rejected"}
    assert orch.policy.fully_released()


def test_fail_with_overlapping_sessions_keeps_slot_accounting_balanced():
    """A failing session releases only its own RUNNING slot: survivors'
    complete() must not steal slots from sessions admitted after recovery
    (regression: fail() used to zero the whole active count)."""
    from repro.core.lifecycle import LifecycleManager

    lm = LifecycleManager()
    lm.prepare("r"); lm.ready("r")
    lm.run("r"); lm.run("r")                    # sessions A and C overlap
    lm.fail("r", "boom", held_slot=True)        # A dies, C still in flight
    assert lm.active_sessions("r") == 1
    lm.complete("r")                            # C finishes after the fail
    assert lm.active_sessions("r") == 0
    lm.recover("r")                             # re-arm the substrate
    lm.run("r")                                 # session B
    lm.complete("r")                            # must NOT raise ready->ready
    assert lm.state("r") == LifecycleState.READY


def test_no_physical_reset_while_sessions_in_flight():
    """Recovery must never reset hardware under a live session: the attempt
    fails (and the control plane falls back) instead."""
    import pytest as _pytest
    from repro.core.invocation import InvocationError

    orch = Orchestrator()
    a = SyntheticAdapter("syn-busy", 2, dwell_s=0.0)
    orch.register(a)
    desc = orch.registry.get("syn-busy")
    s1 = orch.invocations.open_session(_mk_task(1), desc)
    orch.invocations.prepare(s1)
    orch.lifecycle.run("syn-busy")              # a session is on the hardware
    orch.lifecycle.fail("syn-busy", "boom")     # substrate marked failed
    s2 = orch.invocations.open_session(_mk_task(2), desc)
    with _pytest.raises(InvocationError, match="awaiting recovery"):
        orch.invocations.prepare(s2)
    assert a.resets == 0                        # hardware was NOT reset


def test_pooled_and_serial_have_identical_placement_semantics():
    """Scheduling changes timing, never semantics: same fleet, same task mix
    → same per-status counts serial vs pooled."""
    serial_orch, _ = build_orchestrator()
    serial = [serial_orch.submit(_mk_task(i))[0].status for i in range(30)]

    pooled_orch, _ = build_orchestrator()
    with ControlPlaneScheduler(pooled_orch, workers=8) as sched:
        pooled = [r.status for r, _ in
                  sched.submit_many([_mk_task(i) for i in range(30)])]
    assert sorted(serial) == sorted(pooled)
