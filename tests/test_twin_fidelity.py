"""Twin fidelity suite: per-substrate twin-vs-real parity, the
fallback-never-serves-invalid regression, and fidelity-driven health trips.

Parity: every adapter on the standard testbed carries an EXECUTABLE twin
whose shadow divergence against the real invocation stays below the
surrogate's declared tolerance — the measured counterpart of the paper's
twin-synchronization requirement (R5).
"""
import pytest

from repro.core import Orchestrator, TaskRequest
from repro.core.faults import inject_invoke_failure
from repro.core.health import BreakerState
from repro.core.telemetry import TelemetryEvent
from repro.substrates import MemristiveAdapter

# (resource_id, task kwargs) — one case per standard-testbed adapter
SHADOW_CASES = [
    ("chemical-ode",
     dict(function="assay", input_modality="concentration",
          output_modality="concentration",
          payload={"concentrations": [0.6, 0.2, 0.1, 0.1]})),
    ("wetware-synthetic",
     dict(function="screening", input_modality="spikes",
          output_modality="spikes",
          payload={"pattern": [1, 0, 1, 1], "amplitude": 1.0})),
    ("memristive-local",
     dict(function="inference", input_modality="vector",
          output_modality="vector", payload=[0.3, 0.1, 0.4, 0.2])),
    ("fast-external",
     dict(function="inference", input_modality="vector",
          output_modality="vector", payload=[0.3, 0.1, 0.4, 0.2])),
    ("cortical-labs-backend",
     dict(function="screening", input_modality="spikes",
          output_modality="spikes",
          payload={"pattern": [1, 0, 1], "amplitude": 1.0})),
]


def _vector_task(**kw):
    return TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector",
                       payload=[0.2, 0.4, 0.1, 0.3], **kw)


# ---------------------------------------------------------------------------
# shadow parity (per substrate)


@pytest.mark.parametrize("rid,kw", SHADOW_CASES,
                         ids=[rid for rid, _ in SHADOW_CASES])
def test_shadow_divergence_within_declared_tolerance(orchestrator, rid, kw):
    twin = orchestrator.twins.get(rid)
    assert twin is not None and twin.executable, \
        f"{rid} must carry an executable twin"
    tol = twin.surrogate.tolerance
    last_div = None
    # two rounds: record twins are TwinNotReady until the first real
    # invocation has been observed; the second shadow must compare
    for _ in range(2):
        res, trace = orchestrator.submit(
            TaskRequest(backend_preference=rid, twin_mode="shadow", **kw))
        assert res.status == "completed", (rid, res.telemetry)
        last_div = trace.shadow_divergence
    assert last_div is not None, f"{rid}: shadow never produced a comparison"
    assert last_div <= tol, \
        f"{rid}: measured divergence {last_div:.4f} > declared tolerance {tol}"
    # the measured comparison fed the twin state, not just the trace
    assert twin.divergence_ema is not None
    assert twin.fidelity_score > 0.5


def test_shadow_divergence_recorded_in_result_telemetry(orchestrator):
    res, trace = orchestrator.submit(
        _vector_task(backend_preference="memristive-local",
                     twin_mode="shadow"))
    assert res.status == "completed"
    assert res.telemetry["shadow_divergence"] == pytest.approx(
        trace.shadow_divergence, abs=1e-6)


# ---------------------------------------------------------------------------
# fallback regression: NEVER serve from a stale or invalidated twin


def _tripped_single_crossbar(health_cfg=None):
    orch = Orchestrator(health=health_cfg or {"cooldown_s": 60.0})
    orch.register(MemristiveAdapter())
    inj = inject_invoke_failure("memristive-local")
    inj.apply(orch)
    for _ in range(4):
        orch.submit(_vector_task())
    assert orch.health.state("memristive-local") is BreakerState.OPEN
    return orch


def test_fallback_serves_valid_twin_under_quarantine():
    orch = _tripped_single_crossbar()
    res, trace = orch.submit(_vector_task(twin_mode="fallback"))
    assert res.status == "completed"
    assert trace.served_by == "twin"
    assert res.telemetry["served_by"] == "twin"
    assert res.telemetry["twin_mode"] == "fallback"
    assert trace.twin_confidence is not None
    assert trace.selected == "memristive-local"
    log = orch.twin_exec.serve_log()
    assert log and all(e["valid_at_serve"] for e in log)
    assert orch.twin_exec.audit()["twin_serves_invalid"] == 0


def test_fallback_never_serves_stale_twin():
    orch = _tripped_single_crossbar()
    tw = orch.twins.get("memristive-local")
    tw.last_sync -= 3600.0
    res, trace = orch.submit(
        _vector_task(twin_mode="fallback", max_twin_age_ms=60_000.0))
    assert res.status == "rejected"
    assert "stale" in res.telemetry["reason"]
    assert orch.twin_exec.audit()["twin_serves"] == 0
    assert orch.twin_exec.audit()["twin_serves_invalid"] == 0


def test_fallback_never_serves_invalidated_twin_and_surfaces_reason():
    orch = _tripped_single_crossbar()
    orch.twins.invalidate("memristive-local", "manual audit failure")
    res, trace = orch.submit(_vector_task(twin_mode="fallback"))
    assert res.status == "rejected"
    # satellite: the invalidation reason is surfaced in the rejection
    assert "twin invalidated: manual audit failure" in res.telemetry["reason"]
    assert orch.twin_exec.audit()["twin_serves"] == 0
    # explicit recalibration restores twin service
    orch.twins.recalibrate("memristive-local")
    res, trace = orch.submit(_vector_task(twin_mode="fallback"))
    assert res.status == "completed" and trace.served_by == "twin"
    assert all(e["valid_at_serve"] for e in orch.twin_exec.serve_log())


def test_fallback_respects_per_task_confidence_floor():
    orch = _tripped_single_crossbar()
    tw = orch.twins.get("memristive-local")
    tw.confidence = 0.45
    res, _ = orch.submit(
        _vector_task(twin_mode="fallback", twin_min_confidence=0.6))
    assert res.status == "rejected"
    assert "confidence" in res.telemetry["reason"]
    res, trace = orch.submit(
        _vector_task(twin_mode="fallback", twin_min_confidence=0.2))
    assert res.status == "completed" and trace.served_by == "twin"
    assert trace.twin_confidence == pytest.approx(0.45, abs=1e-6)


def test_fallback_requires_twin_to_satisfy_telemetry_contract():
    orch = _tripped_single_crossbar()
    res, _ = orch.submit(_vector_task(
        twin_mode="fallback",
        required_telemetry=("execution_ms", "no_such_field")))
    assert res.status == "rejected"
    assert "telemetry contract" in res.telemetry["reason"]


def test_tasks_without_opt_in_are_rejected_not_twin_served():
    orch = _tripped_single_crossbar()
    res, trace = orch.submit(_vector_task())
    assert res.status == "rejected"
    assert trace.served_by == "substrate"
    assert orch.twin_exec.audit()["twin_serves"] == 0


# ---------------------------------------------------------------------------
# fidelity-driven health trips


def test_measured_divergence_trips_breaker():
    orch = Orchestrator()
    orch.register(MemristiveAdapter())
    rid = "memristive-local"
    # two consecutive comparisons at 8x tolerance => quarantine
    for _ in range(2):
        orch.bus.emit(TelemetryEvent(rid, "twin_shadow", {
            "divergence": 2.0, "tolerance": 0.25, "within": False}))
    assert orch.health.state(rid) is BreakerState.OPEN
    assert "twin fidelity" in orch.health.status()[rid]["open_reason"]


def test_crashing_surrogate_refuses_cleanly_instead_of_escaping():
    """A surrogate that raises inside simulate() must refuse like failing
    hardware — clean rejection with the cause surfaced, never an escaped
    exception (which would kill a scheduler worker on the deadline path)."""
    orch = _tripped_single_crossbar()

    class Boom:
        kind = "behavioral"
        tolerance = 0.25

        def simulate(self, task):
            raise ValueError("boom")

        def observe(self, task, raw):
            pass

        def divergence(self, a, b):
            return 0.0

    orch.twins.get("memristive-local").surrogate = Boom()
    res, _ = orch.submit(_vector_task(twin_mode="fallback"))
    assert res.status == "rejected"
    assert "twin simulate failed: boom" in res.telemetry["reason"]


def test_high_tolerance_surrogate_can_still_quarantine():
    """Divergence metrics clip at 1.0; the capped trip divergence keeps
    fidelity quarantine reachable for tolerance-0.5 surrogates (wetware,
    record, roofline)."""
    orch = Orchestrator()
    orch.register(MemristiveAdapter())
    rid = "memristive-local"
    for _ in range(2):
        orch.bus.emit(TelemetryEvent(rid, "twin_shadow", {
            "divergence": 1.0, "tolerance": 0.5, "within": False}))
    assert orch.health.state(rid) is BreakerState.OPEN


def test_degrade_band_comparison_breaks_the_open_streak():
    """Only consecutive beyond-OPEN comparisons quarantine; a mild
    degrade-band comparison in between resets the streak."""
    orch = Orchestrator()
    orch.register(MemristiveAdapter())
    rid = "memristive-local"
    beyond = {"divergence": 0.16, "tolerance": 0.05}
    mild = {"divergence": 0.08, "tolerance": 0.05}
    orch.bus.emit(TelemetryEvent(rid, "twin_shadow", dict(beyond)))
    orch.bus.emit(TelemetryEvent(rid, "twin_shadow", dict(mild)))
    orch.bus.emit(TelemetryEvent(rid, "twin_shadow", dict(beyond)))
    assert orch.health.state(rid) is BreakerState.DEGRADED
    orch.bus.emit(TelemetryEvent(rid, "twin_shadow", dict(beyond)))
    assert orch.health.state(rid) is BreakerState.OPEN


def test_single_noisy_comparison_only_degrades():
    orch = Orchestrator()
    orch.register(MemristiveAdapter())
    rid = "memristive-local"
    orch.bus.emit(TelemetryEvent(rid, "twin_shadow", {
        "divergence": 2.0, "tolerance": 0.25, "within": False}))
    assert orch.health.state(rid) is BreakerState.DEGRADED
    # a within-tolerance comparison resets the streak; no trip afterwards
    orch.bus.emit(TelemetryEvent(rid, "twin_shadow", {
        "divergence": 0.01, "tolerance": 0.25, "within": True}))
    orch.bus.emit(TelemetryEvent(rid, "twin_shadow", {
        "divergence": 2.0, "tolerance": 0.25, "within": False}))
    assert orch.health.state(rid) is not BreakerState.OPEN


def test_shadow_divergence_end_to_end_quarantines_bad_twin_pairing():
    """A surrogate that stops matching its hardware drives the breaker open
    through REAL shadow runs (no synthetic events)."""
    orch = Orchestrator()
    orch.register(MemristiveAdapter())
    rid = "memristive-local"
    orch.twins.get(rid).surrogate.g = orch.twins.get(rid).surrogate.g + 10.0
    statuses = []
    for _ in range(2):
        res, _ = orch.submit(_vector_task(backend_preference=rid,
                                          twin_mode="shadow"))
        statuses.append(res.status)
    assert statuses == ["completed", "completed"]
    assert orch.health.state(rid) is BreakerState.OPEN
    # the fidelity collapse also shows in twin state the matcher consumes
    assert orch.twins.get(rid).fidelity_score < 0.5
