"""ControlPlaneGateway + ControlPlaneClient end-to-end.

Covers the full endpoint table (discover/describe/invoke/submit/poll/
submit_many/telemetry/health/twin) against the standard mixed testbed, and
— the satellite requirement — produces EVERY structured error code through
a real end-to-end request: breaker-open via fault injection, queue
saturation via a starved scheduler, deadline via a lapsed queue wait,
twin-invalid via an explicit ``invalidate()`` whose recorded reason must
reach the client exception.
"""
import time

import pytest

from repro.core import ErrorCode, Orchestrator, TaskRequest
from repro.core.faults import inject_invoke_failure
from repro.core.health import BreakerState
from repro.gateway import (ControlPlaneClient, ControlPlaneGateway,
                           GatewayError)
from repro.substrates import MemristiveAdapter, standard_testbed


@pytest.fixture()
def plane(fast_service):
    orch = Orchestrator()
    standard_testbed(orch, http_service=fast_service)
    gw = ControlPlaneGateway(orch, plane="test").start()
    try:
        yield orch, gw, ControlPlaneClient(gw.url)
    finally:
        gw.stop()


def _vector_task(**kw):
    return TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector", payload=[0.1, 0.2, 0.3, 0.4],
                       **kw)


# ---------------------------------------------------------------------------
# read surface


def test_discover_matches_local_registry(plane):
    orch, _, client = plane
    remote = {d.resource_id: d for d in client.discover()}
    local = {d.resource_id: d for d in orch.discover()}
    assert remote == local          # faithful from_dict reconstruction
    fast = client.discover(latency_regime="fast_ms", input_modality="vector")
    assert {d.resource_id for d in fast} == \
        {d.resource_id for d in orch.discover(latency_regime="fast_ms",
                                              input_modality="vector")}


def test_describe_and_twin_and_health(plane):
    orch, _, client = plane
    body = client.describe("memristive-local")
    assert body["descriptor"] == orch.registry.get("memristive-local")
    assert body["twin"]["twin_id"] == "twin-memristive-local"
    assert body["snapshot"]["resource_id"] == "memristive-local"
    twin = client.twin("chemical-ode")
    assert twin["kind"] == "ode" and twin["executable"]
    health = client.health()
    assert health["plane"] == "test"
    assert set(health["resources"]) == {d.resource_id
                                        for d in orch.discover()}
    with pytest.raises(GatewayError) as ei:
        client.describe("no-such-resource")
    assert ei.value.code is ErrorCode.NOT_FOUND


# ---------------------------------------------------------------------------
# execution surface


def test_invoke_sync_round_trip(plane):
    orch, _, client = plane
    res, trace = client.invoke(_vector_task(
        required_telemetry=("execution_ms",)))
    assert res.status == "completed"
    assert res.resource_id in ("memristive-local", "fast-external")
    assert trace.selected == res.resource_id
    assert trace.control_overhead_ms > 0.0
    assert res.telemetry["execution_ms"] >= 0.0


def test_submit_poll_and_submit_many(plane):
    _, _, client = plane
    ticket = client.submit(_vector_task())
    res, trace = client.result(ticket, timeout_s=15)
    assert res.status == "completed"
    tickets = client.submit_many([_vector_task() for _ in range(4)])
    assert len(tickets) == len(set(tickets)) == 4
    for t in tickets:
        res, _ = client.result(t, timeout_s=15)
        assert res.status == "completed"
    with pytest.raises(GatewayError) as ei:
        client.poll("ticket-999999")
    assert ei.value.code is ErrorCode.NOT_FOUND


def test_poll_is_deliver_once(plane):
    _, _, client = plane
    ticket = client.submit(_vector_task())
    res, _ = client.result(ticket, timeout_s=15)
    assert res.status == "completed"
    with pytest.raises(GatewayError) as ei:
        client.poll(ticket)
    assert ei.value.code is ErrorCode.NOT_FOUND


def test_malformed_task_is_bad_request_not_internal(plane):
    _, _, client = plane
    from repro.gateway import protocol as wire
    envelope = wire.request_envelope("invoke", {"task": {"payload": [1]}})
    with pytest.raises(GatewayError) as ei:
        client._call("POST", "/v1/invoke", envelope)
    assert ei.value.code is ErrorCode.BAD_REQUEST


def test_submit_many_rejects_whole_batch_on_malformed_task(plane):
    """A malformed task mid-batch must queue NOTHING: earlier tasks
    running with unreturned tickets would double-execute on retry."""
    _, gw, client = plane
    from repro.gateway import protocol as wire
    good = _vector_task().to_wire()
    envelope = wire.request_envelope(
        "submit_many", {"tasks": [good, {"bogus_only": True}]})
    before = gw.scheduler.stats()["done"] + gw.scheduler.pending
    with pytest.raises(GatewayError) as ei:
        client._call("POST", "/v1/submit_many", envelope)
    assert ei.value.code is ErrorCode.BAD_REQUEST
    assert "index 1" in ei.value.message
    assert gw.scheduler.stats()["done"] + gw.scheduler.pending == before


def test_telemetry_limit_zero_is_safe(plane):
    _, _, client = plane
    client.invoke(_vector_task())
    out = client.telemetry(cursor=0, limit=0)     # clamped to 1, not a 500
    assert len(out["events"]) == 1
    with pytest.raises(GatewayError) as ei:
        client._call("GET", "/v1/telemetry?cursor=notanumber")
    assert ei.value.code is ErrorCode.BAD_REQUEST


def test_filtered_long_poll_waits_through_other_traffic(plane):
    """Events from OTHER resources must not cut a filtered long-poll
    short; they are consumed silently (cursor advances past them)."""
    import threading

    _, _, client = plane
    cursor = client.telemetry(cursor=0)["next_cursor"]
    noise = threading.Thread(
        target=lambda: [client.invoke(_vector_task()) for _ in range(3)])
    t0 = time.perf_counter()
    noise.start()
    out = client.telemetry(cursor=cursor, resource="no-such-resource",
                           timeout_s=1.0)
    elapsed = time.perf_counter() - t0
    noise.join()
    assert out["events"] == []
    assert elapsed >= 0.9, "filtered poll returned early on foreign events"
    assert out["next_cursor"] >= cursor


def test_telemetry_long_poll_cursor(plane):
    _, _, client = plane
    first = client.telemetry(cursor=0)
    cursor = first["next_cursor"]
    # nothing new yet: a short long-poll returns empty at the same cursor
    again = client.telemetry(cursor=cursor, timeout_s=0.2)
    assert again["events"] == [] and again["next_cursor"] == cursor
    client.invoke(_vector_task())
    tail = client.telemetry(cursor=cursor, timeout_s=5.0)
    assert tail["events"], "invocation events must reach the cursor log"
    assert all(e["seq"] > cursor for e in tail["events"])
    kinds = {e["kind"] for e in tail["events"]}
    assert "result" in kinds or "lifecycle" in kinds
    # resource filter
    only = client.telemetry(cursor=0, resource="memristive-local")
    assert all(e["resource_id"] == "memristive-local"
               for e in only["events"])


# ---------------------------------------------------------------------------
# error taxonomy, end to end


def test_no_match_code(plane):
    _, _, client = plane
    with pytest.raises(GatewayError) as ei:
        client.invoke(TaskRequest(function="no-such-function",
                                  input_modality="vector",
                                  output_modality="vector"))
    assert ei.value.code is ErrorCode.NO_MATCH
    assert ei.value.trace is not None
    assert ei.value.trace.error_code == ErrorCode.NO_MATCH.value


def test_policy_denied_code(plane):
    _, _, client = plane
    with pytest.raises(GatewayError) as ei:
        client.invoke(TaskRequest(
            function="stimulus_response", input_modality="spikes",
            output_modality="spikes", supervision_available=False,
            backend_preference="wetware-synthetic"))
    assert ei.value.code is ErrorCode.POLICY_DENIED
    assert "supervision" in ei.value.message


def test_breaker_open_code_via_chaos_injector(plane):
    orch, _, client = plane
    injector = inject_invoke_failure("memristive-local")
    injector.apply(orch)
    try:
        # drive failures until the breaker opens (consecutive-failure trip)
        for _ in range(10):
            try:
                client.invoke(_vector_task(
                    backend_preference="memristive-local",
                    allow_fallback=False))
            except GatewayError:
                pass
            if orch.health.state("memristive-local") is BreakerState.OPEN:
                break
        assert orch.health.state("memristive-local") is BreakerState.OPEN
        with pytest.raises(GatewayError) as ei:
            client.invoke(_vector_task(
                backend_preference="memristive-local", allow_fallback=False))
        assert ei.value.code is ErrorCode.BREAKER_OPEN
        assert "quarantined" in ei.value.message
    finally:
        injector.clear(orch)


def test_queue_saturated_code_via_full_scheduler(fast_service):
    """A directed, no-fallback task against a substrate whose only slot is
    held must reject QUEUE_SATURATED once its patience lapses."""
    import dataclasses
    import threading

    class NarrowSlow(MemristiveAdapter):
        def descriptor(self):
            desc = super().descriptor()
            cap = dataclasses.replace(
                desc.capability,
                policy=dataclasses.replace(desc.capability.policy,
                                           max_concurrent=1))
            return dataclasses.replace(desc, capability=cap)

        def invoke(self, session):
            time.sleep(0.5)
            return super().invoke(session)

    orch = Orchestrator(health=False)
    orch.register(NarrowSlow("narrow-slow"))
    gw = ControlPlaneGateway(orch, plane="narrow").start()
    client = ControlPlaneClient(gw.url)
    try:
        blocker = threading.Thread(
            target=lambda: client.invoke(_vector_task(
                backend_preference="narrow-slow")))
        blocker.start()
        time.sleep(0.15)               # let the blocker take the only slot
        with pytest.raises(GatewayError) as ei:
            client.invoke(_vector_task(backend_preference="narrow-slow",
                                       allow_fallback=False,
                                       latency_budget_ms=100.0))
        assert ei.value.code is ErrorCode.QUEUE_SATURATED
        blocker.join()
    finally:
        gw.stop()


def test_deadline_code_via_lapsed_queue_wait(plane):
    _, _, client = plane
    ticket = client.submit(_vector_task(), deadline_s=0.0)
    with pytest.raises(GatewayError) as ei:
        client.result(ticket, timeout_s=15)
    assert ei.value.code is ErrorCode.DEADLINE
    assert "deadline exceeded while queued" in ei.value.message


def test_twin_invalid_code_carries_invalidation_reason(plane):
    orch, _, client = plane
    orch.twins.invalidate("memristive-local",
                          "postcondition: missing drift_score")
    try:
        with pytest.raises(GatewayError) as ei:
            client.invoke(_vector_task(
                backend_preference="memristive-local",
                allow_fallback=False, twin_min_confidence=0.5))
        assert ei.value.code is ErrorCode.TWIN_INVALID
        # PR 3's recorded invalidation reason must reach the remote client
        assert ei.value.invalidation_reason == \
            "postcondition: missing drift_score"
    finally:
        orch.twins.recalibrate("memristive-local")


def test_bad_request_code_on_wrong_version(plane):
    import urllib.request

    _, gw, _ = plane
    from repro.gateway import protocol as wire
    env = wire.request_envelope("invoke", {"task": {}})
    env["protocol_version"] = "9.0"
    req = urllib.request.Request(f"{gw.url}/v1/invoke", data=wire.dumps(env),
                                 headers={"Content-Type": "application/json"})
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req, timeout=5)
    envelope = wire.loads(ei.value.read())
    assert envelope["ok"] is False
    assert envelope["error"]["code"] == ErrorCode.BAD_REQUEST.value
    assert ei.value.code == 400


def test_plane_unavailable_code_after_stop(fast_service):
    orch = Orchestrator()
    standard_testbed(orch, http_service=fast_service)
    gw = ControlPlaneGateway(orch, plane="dying").start()
    client = ControlPlaneClient(gw.url, timeout_s=2.0)
    assert client.health()["plane"] == "dying"
    gw.stop()
    with pytest.raises(GatewayError) as ei:
        client.health()
    assert ei.value.code is ErrorCode.PLANE_UNAVAILABLE
