"""Wire protocol v1: faithful round-trips, error taxonomy, envelopes.

The descriptor round-trip property (``to_dict → from_dict → to_dict``
identity over generated descriptors) is the executable form of the paper's
descriptor-portability claim, independent of any gateway being up.
"""
import pytest

from repro.core import (ControlPlaneError, ErrorCode, InvocationResult,
                        TaskRequest, WireError, classify_rejection,
                        new_task_id, set_plane_namespace)
from repro.core.descriptors import (CapabilityDescriptor, LifecycleSemantics,
                                    Observability, PolicyConstraints,
                                    ResourceDescriptor, SignalSpec,
                                    TimingSemantics)
from repro.core.orchestrator import OrchestrationTrace
from repro.core.telemetry import RuntimeSnapshot
from repro.gateway import protocol as wire
from repro.substrates import (ChemicalAdapter, CorticalLabsAdapter,
                              MemristiveAdapter, WetwareAdapter)


# ---------------------------------------------------------------------------
# TaskRequest wire fidelity (satellite: to_dict used to discard the payload)


def test_task_to_wire_keeps_payload():
    t = TaskRequest(function="inference", input_modality="vector",
                    output_modality="vector", payload=[0.1, 0.2],
                    required_telemetry=("execution_ms",),
                    metadata={"k": "v"})
    w = t.to_wire()
    assert w["payload"] == [0.1, 0.2]
    back = TaskRequest.from_wire(w)
    assert back == t
    assert back.task_id == t.task_id           # identity survives the hop
    assert back.required_telemetry == ("execution_ms",)


def test_task_summary_redacts_payload_and_to_dict_aliases_it():
    t = TaskRequest(function="inference", input_modality="vector",
                    output_modality="vector", payload=[0.1, 0.2])
    assert t.summary()["payload"] == "<payload>"
    assert t.to_dict() == t.summary()
    none = TaskRequest(function="f", input_modality="a", output_modality="b")
    assert none.summary()["payload"] is None


def test_task_from_wire_ignores_unknown_fields():
    t = TaskRequest(function="f", input_modality="a", output_modality="b")
    w = t.to_wire()
    w["future_field_from_v1_1"] = {"x": 1}     # additive minor-version field
    assert TaskRequest.from_wire(w) == t


def test_descriptor_from_dict_ignores_unknown_fields():
    """Additive MINOR-version fields in ANY nested spec must be skipped,
    not crash reconstruction (the protocol compatibility policy)."""
    desc = MemristiveAdapter().descriptor()
    d = desc.to_dict()
    d["new_top_level"] = 1
    d["capability"]["new_cap_field"] = 2
    for spec in ("input_signal", "timing", "lifecycle", "observability",
                 "policy"):
        d["capability"][spec]["new_spec_field"] = 3
    assert ResourceDescriptor.from_dict(d) == desc


def test_unserializable_payload_is_refused_loudly():
    """A payload the wire cannot carry faithfully must error, never be
    silently stringified into junk the remote plane executes on."""
    from repro.gateway.protocol import ProtocolError
    t = TaskRequest(function="f", input_modality="a", output_modality="b",
                    payload=b"\x01\x02")
    with pytest.raises(ProtocolError):
        wire.dumps(wire.request_envelope("invoke",
                                         {"task": t.to_wire()}))


def test_task_ids_are_plane_namespaced():
    prev = set_plane_namespace("edge")
    try:
        a = new_task_id()
        set_plane_namespace("cloud")
        b = new_task_id()
        assert a.startswith("task-edge-")
        assert b.startswith("task-cloud-")
        assert a.split("-")[-1] != b.split("-")[-1] or a != b
        assert TaskRequest(function="f", input_modality="a",
                           output_modality="b").task_id.startswith("task-cloud-")
    finally:
        set_plane_namespace(prev)


# ---------------------------------------------------------------------------
# descriptor round-trips — concrete adapters first


@pytest.mark.parametrize("adapter_cls", [ChemicalAdapter, WetwareAdapter,
                                         MemristiveAdapter,
                                         CorticalLabsAdapter])
def test_adapter_descriptor_roundtrip(adapter_cls):
    desc = adapter_cls().descriptor()
    d = desc.to_dict()
    back = ResourceDescriptor.from_dict(d)
    assert back == desc
    assert back.to_dict() == d


def test_nested_spec_roundtrips():
    sig = SignalSpec("vector", "float32", (-1.0, 1.0), sampling_hz=10.0,
                     transduction="dac")
    assert SignalSpec.from_dict(sig.to_dict()) == sig
    tim = TimingSemantics("fast_ms", 2.0, 5.0, trigger_mode="stream")
    assert TimingSemantics.from_dict(tim.to_dict()) == tim
    lc = LifecycleSemantics(warmup_ms=2.0, reset_modes=("soft", "hard"),
                            recovery_modes=("flush",),
                            calibration_interval_s=60.0)
    assert LifecycleSemantics.from_dict(lc.to_dict()) == lc
    obs = Observability(("ch",), ("f1", "f2"), ("d",), ("t",))
    assert Observability.from_dict(obs.to_dict()) == obs
    pol = PolicyConstraints(exclusive=False, max_concurrent=4,
                            authorized_tenants=("a", "b"), biosafety_level=2)
    assert PolicyConstraints.from_dict(pol.to_dict()) == pol


# ---------------------------------------------------------------------------
# descriptor round-trip PROPERTY (hypothesis-generated descriptors) — the
# rest of the module must still run when hypothesis is absent

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _names = st.text(alphabet="abcdefghijklmnopqrstuvwxyz_", min_size=1,
                     max_size=12)
    _floats = st.floats(0.0, 1e6, allow_nan=False, allow_infinity=False)
    _opt_floats = st.none() | _floats
    _str_tuples = st.tuples() | st.lists(_names, max_size=4).map(tuple)

    _signals = st.builds(
        SignalSpec, modality=_names, encoding=_names,
        admissible_range=st.tuples(st.floats(-1e6, 0.0, allow_nan=False),
                                   st.floats(0.0, 1e6, allow_nan=False)),
        sampling_hz=_opt_floats, transduction=st.none() | _names)

    _timings = st.builds(
        TimingSemantics,
        latency_regime=st.sampled_from(("slow_seconds", "fast_ms", "sub_ms")),
        expected_latency_ms=_floats, observation_window_ms=_floats,
        min_stabilization_ms=_floats,
        trigger_mode=st.sampled_from(("request", "stream", "event")),
        freshness_ms=_floats)

    _lifecycles = st.builds(
        LifecycleSemantics, warmup_ms=_floats, resetable=st.booleans(),
        reset_modes=_str_tuples, reset_cost_ms=_floats,
        calibration_interval_s=_opt_floats, recovery_modes=_str_tuples,
        cooldown_ms=_floats)

    _observabilities = st.builds(
        Observability, output_channels=_str_tuples,
        telemetry_fields=_str_tuples, drift_indicators=_str_tuples,
        twin_linked_fields=_str_tuples)

    _policies = st.builds(
        PolicyConstraints, exclusive=st.booleans(),
        requires_supervision=st.booleans(), max_stimulation=_opt_floats,
        max_concurrent=st.integers(1, 64),
        authorized_tenants=st.just(("*",)) | _str_tuples,
        biosafety_level=st.integers(0, 4))

    _capabilities = st.builds(
        CapabilityDescriptor, functions=_str_tuples, input_signal=_signals,
        output_signal=_signals, timing=_timings, lifecycle=_lifecycles,
        programmability=st.sampled_from(("fixed", "configurable", "tunable",
                                         "in_situ_adaptive")),
        observability=_observabilities, policy=_policies,
        supports_repeated_invocation=st.booleans(),
        energy_proxy_mj=_opt_floats)

    _descriptors = st.builds(
        ResourceDescriptor, resource_id=_names, substrate_class=_names,
        adapter_type=st.sampled_from(("in_process", "http", "external_api")),
        location=st.sampled_from(("extreme_edge", "edge", "fog", "cloud",
                                  "lab")),
        twin_binding=st.none() | _names, capability=_capabilities,
        description=_names)

    @settings(max_examples=60, deadline=None)
    @given(desc=_descriptors)
    def test_descriptor_wire_roundtrip_property(desc):
        """to_dict → from_dict → to_dict is an identity, and the rebuilt
        descriptor equals the original (frozen dataclass equality)."""
        d = desc.to_dict()
        back = ResourceDescriptor.from_dict(d)
        assert back == desc
        assert back.to_dict() == d
        # the wire form must actually be JSON-transportable
        assert ResourceDescriptor.from_dict(wire.loads(wire.dumps(d))) == desc
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_descriptor_wire_roundtrip_property():
        pass


# ---------------------------------------------------------------------------
# result / trace / snapshot round-trips


def test_result_and_trace_roundtrip():
    res = InvocationResult(task_id="task-x-00001", resource_id="r",
                           status="completed", output={"vector": [1.0, 2.0]},
                           telemetry={"execution_ms": 1.2}, artifacts={},
                           timing_ms={"backend_ms": 1.0, "total_ms": 2.0},
                           contracts={}, session_id="session-00001")
    assert InvocationResult.from_wire(res.to_wire()) == res
    trace = OrchestrationTrace("task-x-00001")
    trace.record_attempt({"resource": "r", "score": 1.0, "terms": {}})
    trace.selected = "r"
    trace.error_code = None
    back = OrchestrationTrace.from_wire(trace.to_wire())
    assert back == trace


def test_snapshot_roundtrip():
    snap = RuntimeSnapshot("r", health_status="degraded", drift_score=0.2,
                           queue_depth=3)
    back = wire.snapshot_from_wire(wire.snapshot_to_wire(snap))
    assert (back.resource_id, back.health_status, back.drift_score,
            back.queue_depth) == ("r", "degraded", 0.2, 3)


# ---------------------------------------------------------------------------
# error taxonomy


@pytest.mark.parametrize("reason,code", [
    ("no acceptable backend candidate: r=input modality mismatch",
     ErrorCode.NO_MATCH),
    ("circuit open (quarantined): 3 consecutive failures",
     ErrorCode.BREAKER_OPEN),
    ("probation trickle budget exhausted", ErrorCode.BREAKER_OPEN),
    ("concurrency limit", ErrorCode.QUEUE_SATURATED),
    ("queue saturated (depth 9 >= 3)", ErrorCode.QUEUE_SATURATED),
    ("deadline exceeded while queued", ErrorCode.DEADLINE),
    ("twin invalidated: postcondition: missing drift", ErrorCode.TWIN_INVALID),
    ("twin stale (99ms > 10ms)", ErrorCode.TWIN_INVALID),
    ("twin confidence 0.10 < 0.3", ErrorCode.TWIN_INVALID),
    ("substrate requires human supervision; task declares none available",
     ErrorCode.POLICY_DENIED),
    ("tenant 'x' not authorized", ErrorCode.POLICY_DENIED),
    ("fallback attempts exhausted", ErrorCode.FALLBACK_EXHAUSTED),
    ("prepare failure: injected preparation failure",
     ErrorCode.FALLBACK_EXHAUSTED),
    ("resource unregistered", ErrorCode.NOT_FOUND),
])
def test_classify_rejection(reason, code):
    assert classify_rejection(reason) is code


def test_wire_error_roundtrip():
    err = WireError(ErrorCode.BREAKER_OPEN, "quarantined",
                    {"trace": {"task_id": "t"}})
    back = WireError.from_wire(wire.loads(wire.dumps(err.to_wire())))
    assert back.code is ErrorCode.BREAKER_OPEN
    assert back.message == "quarantined"
    assert back.detail["trace"] == {"task_id": "t"}
    assert WireError.from_wire({"code": "NOT_A_CODE"}).code is \
        ErrorCode.INTERNAL


def test_rejection_to_error_extracts_invalidation_reason():
    res = InvocationResult(
        task_id="t", resource_id="", status="rejected", output=None,
        telemetry={"reason": "twin invalidated: speculation mismatch: "
                             "divergence 0.9 > tolerance 0.25",
                   "error_code": "TWIN_INVALID"},
        artifacts={}, timing_ms={}, contracts={}, session_id="")
    err = wire.rejection_to_error(res, OrchestrationTrace("t"))
    assert err.code is ErrorCode.TWIN_INVALID
    assert err.detail["invalidation_reason"].startswith(
        "speculation mismatch")
    assert err.detail["trace"]["task_id"] == "t"


# ---------------------------------------------------------------------------
# envelopes + versioning


def test_envelope_roundtrip_and_version_policy():
    env = wire.request_envelope("invoke", {"task": {}})
    assert env["protocol_version"] == wire.PROTOCOL_VERSION
    assert wire.parse_request(env, expect_kind="invoke") == {"task": {}}
    with pytest.raises(wire.ProtocolError):
        wire.parse_request(dict(env, protocol_version="9.0"))
    with pytest.raises(wire.ProtocolError):
        wire.parse_request(dict(env, kind="discover"), expect_kind="invoke")
    # minor version drift within the same major parses fine
    wire.parse_request(dict(env, protocol_version="1.7"),
                       expect_kind="invoke")


def test_parse_response_raises_structured_error():
    env = wire.error_envelope("invoke", WireError(
        ErrorCode.QUEUE_SATURATED, "full", {"retry_after_s": 1}))
    with pytest.raises(ControlPlaneError) as ei:
        wire.parse_response(env)
    assert ei.value.code is ErrorCode.QUEUE_SATURATED
    assert ei.value.detail["retry_after_s"] == 1
    ok = wire.ok_envelope("invoke", {"x": 1})
    assert wire.parse_response(ok) == {"x": 1}


def test_http_status_mapping_is_total():
    for code in ErrorCode:
        assert 400 <= wire.http_status(code) <= 599


def test_rejected_result_carries_error_code():
    from repro.core import Orchestrator
    orch = Orchestrator()
    res, trace = orch.submit(TaskRequest(
        function="inference", input_modality="vector",
        output_modality="vector"))
    assert res.status == "rejected"
    assert res.telemetry["error_code"] == ErrorCode.NO_MATCH.value
    assert trace.error_code == ErrorCode.NO_MATCH.value
    assert res.error_code == ErrorCode.NO_MATCH.value
