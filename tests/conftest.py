import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 host devices.


@pytest.fixture(scope="session")
def fast_service():
    from repro.substrates.http_fast import FastService

    svc = FastService().start()
    yield svc
    svc.stop()


@pytest.fixture()
def orchestrator(fast_service):
    from repro.core import Orchestrator
    from repro.substrates import standard_testbed

    orch = Orchestrator()
    standard_testbed(orch, http_service=fast_service)
    return orch


def make_testbed_factory(fast_service):
    from repro.core import Orchestrator
    from repro.substrates import standard_testbed

    def factory():
        orch = Orchestrator()
        standard_testbed(orch, http_service=fast_service)
        return orch

    return factory
