"""Integration gate over the cached multi-pod dry-run results.

The dry-run itself needs 512 host devices and minutes of XLA time, so tests
assert on its cached artifacts (benchmarks/results/dryrun) rather than
recompiling. Deliverable (e): every applicable (arch × shape × mesh) cell
must lower+compile; failures there are bugs in the system.
"""
import json
from pathlib import Path

import pytest

from repro.configs import SHAPES, get_config, list_archs, supports_shape

DRYRUN = Path(__file__).resolve().parents[1] / "benchmarks" / "results" / "dryrun"

pytestmark = pytest.mark.skipif(
    not DRYRUN.exists() or not list(DRYRUN.glob("*.json")),
    reason="dry-run cache missing (run python -m repro.launch.dryrun --all)")


def _cells():
    return [json.loads(f.read_text()) for f in sorted(DRYRUN.glob("*.json"))]


def test_no_failed_cells():
    failed = [c["cell"] for c in _cells() if c.get("status") == "failed"]
    assert not failed, failed


def test_every_applicable_cell_present_and_ok():
    cells = {c["cell"]: c for c in _cells()}
    missing, bad = [], []
    for mesh_tag in ("pod256", "pod512"):
        for arch in list_archs():
            for shape_name, shape in SHAPES.items():
                cid = f"{arch}__{shape_name}__{mesh_tag}__baseline"
                c = cells.get(cid)
                if c is None:
                    missing.append(cid)
                    continue
                ok, _ = supports_shape(get_config(arch), shape)
                want = "ok" if ok else "skipped"
                if c["status"] != want:
                    bad.append((cid, c["status"], want))
    assert not missing, missing
    assert not bad, bad


def test_skips_match_capability_model():
    """Exactly the quadratic-attention archs skip long_500k."""
    for c in _cells():
        if c["shape"] == "long_500k" and c["recipe"] == "baseline":
            runs = c["arch"] in ("rwkv6-7b", "recurrentgemma-9b")
            assert (c["status"] == "ok") == runs, (c["cell"], c["status"])


def test_roofline_terms_recorded_for_ok_cells():
    for c in _cells():
        if c.get("status") != "ok":
            continue
        r = c["roofline"]
        assert r["compute_s"] >= 0 and r["memory_s"] > 0
        assert r["dominant"] in ("compute", "memory", "collective")
        assert c["cost"]["flops_per_device"] > 0
        assert "fits" in c["memory"] and "fits_with_donation" in c["memory"]


def test_train_cells_fit_with_donation():
    """HBM deliverable: all train cells fit once donation aliasing is
    accounted for (two documented CPU-artifact exceptions allowed)."""
    over = []
    for c in _cells():
        if c.get("status") == "ok" and c["kind"] == "train":
            if not c["memory"]["fits_with_donation"]:
                over.append(c["cell"])
    # nemotron single/multi-pod baseline carries the fp32-boundary-stack CPU
    # artifact (EXPERIMENTS.md §Dry-run); its fsdp_pod multi-pod variant fits
    allowed = {x for x in over if x.startswith("nemotron-4-340b")}
    assert set(over) <= allowed, over
