"""Paged-KV serving tests: parity, capacity, saturation, clock injection.

The paged path must be *token-for-token identical* to the slot-granular
path — paging changes where KV bytes live, never what attention reads.
Parity runs across the cache families (pure global attention, MLA latents,
and the hybrid ring-buffer stack that degrades to slot-granular), then the
capacity properties: a request longer than the old per-slot cap completes
under the same HBM budget, pool exhaustion refuses with a structured
``QUEUE_SATURATED`` + ``retry_after_s``, and a drained engine holds zero
leaked pages.
"""
import threading

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.errors import AdmissionRefused, ErrorCode
from repro.core.simclock import VirtualClock
from repro.models import model_specs
from repro.models.common import init_params
from repro.roofline.serving import ServingCostModel
from repro.serving import Request, ServingEngine

#: one arch per cache family: pure global-attention KV, MLA latent KV, and
#: a recurrent/ring hybrid with no pageable leaves at all
FAMILIES = ["internlm2-20b", "deepseek-v2-236b", "recurrentgemma-9b"]


@pytest.fixture(scope="module", params=FAMILIES)
def fam(request):
    cfg = reduced(get_config(request.param))
    return request.param, cfg, init_params(model_specs(cfg), seed=1)


@pytest.fixture(scope="module")
def attn():
    cfg = reduced(get_config("internlm2-20b"))
    return cfg, init_params(model_specs(cfg), seed=1)


def make_prompt(rng, cfg, n):
    return rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)


def run_trace(eng, prompts, max_new):
    reqs = [eng.submit(Request(f"r{i}", p, max_new_tokens=mn))
            for i, (p, mn) in enumerate(zip(prompts, max_new))]
    eng.drain()
    return [r.generated for r in reqs]


# -- parity -------------------------------------------------------------------

def test_paged_parity_token_for_token(fam):
    arch, cfg, params = fam
    rng = np.random.default_rng(11)
    # mixed lengths + one long-decode request that grows across several
    # page boundaries mid-flight
    prompts = [make_prompt(rng, cfg, n) for n in (5, 12, 9, 17, 3)]
    max_new = [6, 6, 6, 6, 21]
    base = ServingEngine(cfg, params=params, batch_size=3, max_seq=64)
    paged = ServingEngine(cfg, params=params, batch_size=3, max_seq=64,
                          paged=True, page_size=8, pool_pages=48)
    a = run_trace(base, prompts, max_new)
    b = run_trace(paged, prompts, max_new)
    assert a == b, f"{arch}: paged decode diverged from contiguous"
    if arch == "recurrentgemma-9b":
        # no pageable leaves: paged mode degrades to slot-granular
        assert paged.pool_stats() == {}
    else:
        assert paged.pool_stats()["pool_pages"] == 48


def test_prefix_reuse_parity_and_suffix_only_prefill(attn):
    cfg, params = attn
    rng = np.random.default_rng(12)
    common = make_prompt(rng, cfg, 24)
    prompts = [np.concatenate([common, make_prompt(rng, cfg, 4 + i)])
               for i in range(4)]
    max_new = [5] * len(prompts)
    base = ServingEngine(cfg, params=params, batch_size=2, max_seq=64)
    paged = ServingEngine(cfg, params=params, batch_size=2, max_seq=64,
                          paged=True, page_size=8, pool_pages=64)
    prefilled = []
    paged.on_prefill_ms = lambda tokens, ms: prefilled.append(tokens)
    a = run_trace(base, prompts, max_new)
    b = run_trace(paged, prompts, max_new)
    assert a == b, "prefix-shared decode diverged from contiguous"
    # first request prefills everything; the sharers only their suffix
    assert prefilled[0] == len(prompts[0])
    assert all(t <= len(p) - 24 for t, p in zip(prefilled[1:], prompts[1:]))
    stats = paged.pool_stats()
    assert stats["prefix_hit_rate"] > 0.5
    assert paged.cached_prefix_tokens(prompts[0]) >= 24


# -- capacity -----------------------------------------------------------------

def test_request_longer_than_slot_granular_cap_completes(attn):
    """Same KV HBM budget (64 cacheable tokens), opposite capacity shape:
    the slot-granular engine caps every request at 32 tokens; the paged
    engine serves one 49-token request by giving it 7 of the 8 pages."""
    cfg, params = attn
    rng = np.random.default_rng(13)
    prompt = make_prompt(rng, cfg, 40)
    old = ServingEngine(cfg, params=params, batch_size=2, max_seq=32)
    with pytest.raises(AdmissionRefused) as ei:
        old.submit(Request("long", prompt, max_new_tokens=9))
    assert ei.value.code == ErrorCode.BAD_REQUEST
    paged = ServingEngine(cfg, params=params, batch_size=2, max_seq=64,
                          paged=True, page_size=8, pool_pages=8,
                          prefix_sharing=False)
    r = paged.submit(Request("long", prompt, max_new_tokens=9))
    paged.drain()
    assert r.done and len(r.generated) == 9
    # reference: the same request on a contiguous 64-token engine
    ref = ServingEngine(cfg, params=params, batch_size=1, max_seq=64)
    [ref_r] = ref.generate([Request("ref", prompt, max_new_tokens=9)])
    assert r.generated == ref_r.generated
    assert paged.audit_pages()["used"] == 0


def test_pool_exhaustion_refuses_queue_saturated(attn):
    cfg, params = attn
    rng = np.random.default_rng(14)
    eng = ServingEngine(cfg, params=params, batch_size=2, max_seq=64,
                        paged=True, page_size=8, pool_pages=8,
                        prefix_sharing=False)
    held = [eng.submit(Request(f"h{i}", make_prompt(rng, cfg, 20),
                               max_new_tokens=12)) for i in range(2)]
    backlog_before = eng.backlog_tokens()
    with pytest.raises(AdmissionRefused) as ei:
        eng.submit(Request("over", make_prompt(rng, cfg, 20),
                           max_new_tokens=12))
    e = ei.value
    assert e.code == ErrorCode.QUEUE_SATURATED
    assert "queue saturated" in e.message
    assert e.detail["retry_after_s"] > 0
    assert e.detail["needed_pages"] == 4
    assert e.detail["pool_pages"] == 8
    # the refusal touched no engine state
    assert eng.backlog_tokens() == backlog_before
    eng.drain()
    assert all(r.done for r in held)
    # capacity freed: the refused request now admits and completes
    r = eng.submit(Request("retry", make_prompt(rng, cfg, 20),
                           max_new_tokens=12))
    eng.drain()
    assert r.done and len(r.generated) == 12
    assert eng.audit_pages() == {"pool_pages": 8, "used": 0, "free": 8,
                                 "reserved": 0}


def test_no_page_leaks_after_drain_and_flush(attn):
    cfg, params = attn
    rng = np.random.default_rng(15)
    eng = ServingEngine(cfg, params=params, batch_size=2, max_seq=64,
                        paged=True, page_size=8, pool_pages=64)
    prompts = [make_prompt(rng, cfg, n) for n in (5, 12, 9)]
    run_trace(eng, prompts, [4, 4, 4])
    # after drain the only live pages are prefix-cache references
    stats = eng.audit_pages()
    assert stats["reserved"] == 0
    assert stats["used"] == eng.pool_stats()["pool_pages_used"]
    eng.flush()
    assert eng.audit_pages()["used"] == 0


def test_flush_releases_reservations_of_queued_work(attn):
    cfg, params = attn
    rng = np.random.default_rng(16)
    eng = ServingEngine(cfg, params=params, batch_size=2, max_seq=64,
                        paged=True, page_size=8, pool_pages=8,
                        prefix_sharing=False)
    for i in range(2):
        eng.submit(Request(f"q{i}", make_prompt(rng, cfg, 20),
                           max_new_tokens=12))
    assert eng.audit_pages()["reserved"] == 8
    eng.flush()
    assert eng.audit_pages() == {"pool_pages": 8, "used": 0, "free": 8,
                                 "reserved": 0}
    assert eng.backlog_tokens() == 0


# -- backlog split ------------------------------------------------------------

def test_backlog_counts_unprefilled_prompt_tokens(attn):
    cfg, params = attn
    rng = np.random.default_rng(17)
    eng = ServingEngine(cfg, params=params, batch_size=2, max_seq=64)
    eng.submit(Request("a", make_prompt(rng, cfg, 10), max_new_tokens=4))
    eng.submit(Request("b", make_prompt(rng, cfg, 7), max_new_tokens=3))
    b = eng.backlog()
    assert b["prefill_tokens"] == 17
    assert b["decode_tokens"] == 7
    assert eng.backlog_tokens() == 24
    eng.drain()
    assert eng.backlog_tokens() == 0


def test_cost_model_prices_prefill_backlog_and_prefix_hits():
    cfg = reduced(get_config("internlm2-20b"))
    cost = ServingCostModel(cfg, batch_size=2, max_seq=64,
                            page_size=8, pool_pages=16)
    base = cost.predict_request_ms(32, 8)
    with_backlog = cost.predict_request_ms(32, 8, backlog_prefill_tokens=64)
    with_prefix = cost.predict_request_ms(32, 8, cached_prefix_tokens=24)
    assert with_backlog > base
    assert with_prefix < base
    assert cost.bytes_per_page > 0
    assert cost.page_hbm_bytes(4) == (cost.resident_cache_bytes
                                      + 4 * cost.bytes_per_page)
    assert cost.page_hbm_bytes(4, 2) > cost.page_hbm_bytes(4)


# -- clock seam ---------------------------------------------------------------

def test_engine_stamps_requests_on_injected_clock(attn):
    cfg, params = attn
    clk = VirtualClock()
    eng = ServingEngine(cfg, params=params, batch_size=2, max_seq=64,
                        clock=clk)
    rng = np.random.default_rng(18)
    r = Request("v", make_prompt(rng, cfg, 6), max_new_tokens=3)
    eng.submit(r)
    clk.advance(1.5)                        # queue wait, in virtual time
    eng.drain()
    assert r.arrived_s == 0.0
    assert r.first_token_s == pytest.approx(1.5)
    assert r.ttft_ms == pytest.approx(1500.0)


def test_serve_forever_parks_unbounded_and_wakes_on_stop(attn):
    """The idle driver must not poll: with no work it parks on the engine
    condition until ``wake`` — and observes a stop immediately after."""
    cfg, params = attn
    eng = ServingEngine(cfg, params=params, batch_size=2, max_seq=64)
    stop = threading.Event()
    driver = threading.Thread(target=eng.serve_forever, args=(stop,),
                              daemon=True)
    driver.start()
    # park is unbounded (idle_wait_s=None): the thread stays alive, blocked
    driver.join(timeout=0.2)
    assert driver.is_alive()
    stop.set()
    eng.wake()
    driver.join(timeout=2.0)
    assert not driver.is_alive(), "driver did not wake on stop"
