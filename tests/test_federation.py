"""Federated planes: an edge gateway as one substrate of a cloud plane.

The acceptance demo for the protocol-first redesign: an edge control plane
with two physical substrates sits behind a ControlPlaneGateway; a cloud
orchestrator registers that whole plane as ONE RemotePlaneAdapter.  Tasks
submitted to the cloud execute on edge hardware with a complete
OrchestrationTrace across the boundary; killing the edge gateway
mid-stream trips the cloud-side circuit breaker, and opted-in traffic is
served from the cloud's twin of the plane with zero invalid serves.
"""
import time

import pytest

from repro.core import (ControlPlaneScheduler, ErrorCode, Orchestrator,
                        TaskRequest)
from repro.core.health import BreakerState
from repro.gateway import ControlPlaneGateway
from repro.substrates import (ChemicalAdapter, MemristiveAdapter,
                              RemotePlaneAdapter, federate, federate_all)

EDGE_SUBSTRATES = ("edge-crossbar-a", "edge-crossbar-b")


@pytest.fixture()
def edge_plane():
    orch = Orchestrator()
    for rid in EDGE_SUBSTRATES:
        orch.register(MemristiveAdapter(rid))
    gw = ControlPlaneGateway(orch, plane="edge").start()
    try:
        yield orch, gw
    finally:
        gw.stop()


def _cloud(consecutive_failures_to_open: int = 2) -> Orchestrator:
    return Orchestrator(health=dict(
        cooldown_s=30.0,               # stays OPEN for the whole test
        thresholds={"consecutive_failures_to_open":
                    consecutive_failures_to_open}))


def _vector_task(**kw):
    return TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector", payload=[0.1, 0.2, 0.3, 0.4],
                       **kw)


def test_descriptor_aggregates_edge_fleet(edge_plane):
    _, gw = edge_plane
    adapter = RemotePlaneAdapter(gw.url)
    desc = adapter.descriptor()
    assert desc.resource_id == "plane-edge"
    assert desc.substrate_class == "federated_plane"
    assert desc.adapter_type == "http"
    cap = desc.capability
    assert set(cap.functions) == {"inference", "mvm"}      # union
    assert cap.policy.max_concurrent == 8                  # 4 + 4, summed
    assert "transport_ms" in cap.observability.telemetry_fields
    assert "drift_score" in cap.observability.drift_indicators
    # advertised latency carries the wire margin on top of the fastest member
    assert cap.timing.expected_latency_ms > 2.0


def test_cloud_task_executes_on_edge_with_complete_trace(edge_plane):
    _, gw = edge_plane
    cloud = _cloud()
    adapter = federate(cloud, gw.url)
    task = _vector_task(required_telemetry=("execution_ms",))
    res, trace = cloud.submit(task)
    assert res.status == "completed"
    # cloud-side trace: the plane was the selected "substrate"
    assert trace.selected == adapter.resource_id
    assert res.resource_id == adapter.resource_id
    # the task kept ONE identity across the hop
    remote_trace = res.artifacts["remote_trace"]
    assert remote_trace["task_id"] == task.task_id
    # edge-side trace rides home complete: placement + overhead + attempts
    assert remote_trace["selected"] in EDGE_SUBSTRATES
    assert remote_trace["attempts"]
    assert remote_trace["control_overhead_ms"] > 0.0
    assert res.telemetry["remote_resource_id"] in EDGE_SUBSTRATES
    assert res.telemetry["remote_plane"] == "edge"
    assert res.telemetry["transport_ms"] >= 0.0
    assert res.artifacts["remote_session_id"].startswith("session-")


def test_edge_members_share_load_through_one_adapter(edge_plane):
    _, gw = edge_plane
    cloud = _cloud()
    federate(cloud, gw.url)
    placed = set()
    for _ in range(12):
        res, _ = cloud.submit(_vector_task())
        assert res.status == "completed"
        placed.add(res.telemetry["remote_resource_id"])
    # the REMOTE matcher owns member placement; over a dozen tasks the
    # edge plane exercises its fleet (both crossbars are equivalent, so
    # at least one serves — drift steering may concentrate load)
    assert placed <= set(EDGE_SUBSTRATES) and placed


def test_gateway_kill_trips_breaker_and_twin_serves(edge_plane):
    """The federation acceptance demo, mid-stream through the scheduler."""
    _, gw = edge_plane
    cloud = _cloud(consecutive_failures_to_open=2)
    adapter = federate(cloud, gw.url)
    rid = adapter.resource_id
    with ControlPlaneScheduler(cloud, workers=4) as sched:
        # phase 1: healthy stream — twin learns from every forwarded result
        warm = sched.submit_many([_vector_task() for _ in range(6)])
        assert all(r.status == "completed" for r, _ in warm)
        assert all(t.served_by == "substrate" for _, t in warm)
        twin = cloud.twins.get(rid)
        assert twin is not None and twin.observations >= 6

        # phase 2: the edge gateway dies mid-stream
        gw.stop()
        outcomes = sched.submit_many(
            [_vector_task(twin_mode="fallback") for _ in range(10)])

        # the cloud-side breaker quarantined the whole plane
        assert cloud.health.state(rid) is BreakerState.OPEN
        # opted-in traffic kept completing, served by the plane's twin
        twin_served = [(r, t) for r, t in outcomes if t.served_by == "twin"]
        assert twin_served, "twin must serve while the plane is quarantined"
        assert all(r.status == "completed" for r, _ in outcomes)
        for r, t in twin_served:
            assert r.telemetry["served_by"] == "twin"
            assert r.telemetry["twin_id"] == f"twin-{rid}"
            assert t.twin_confidence is not None
        # ZERO serves from invalid twins (PR 3 invariant, across planes)
        audit = cloud.twin_exec.audit()
        assert audit["twin_serves_invalid"] == 0
        assert audit["twin_serves"] >= len(twin_served)

        # phase 3: tasks that did NOT opt in reject with a structured code
        res, trace = sched.submit_async(_vector_task()).result()
        assert res.status == "rejected"
        assert trace.error_code in (ErrorCode.BREAKER_OPEN.value,
                                    ErrorCode.NO_MATCH.value,
                                    ErrorCode.FALLBACK_EXHAUSTED.value)


def test_empty_modality_profile_rejects_structured(edge_plane):
    from repro.core import ControlPlaneError, ErrorCode

    _, gw = edge_plane
    with pytest.raises(ControlPlaneError) as ei:
        RemotePlaneAdapter(gw.url, modality=("spikes", "spikes"))
    assert ei.value.code is ErrorCode.NO_MATCH


def test_unreachable_plane_snapshot_reports_down(edge_plane):
    _, gw = edge_plane
    adapter = RemotePlaneAdapter(gw.url)
    snap = adapter.snapshot()
    assert snap.health_status == "healthy"
    gw.stop()
    snap = adapter.snapshot()
    assert snap.health_status == "failed" and snap.readiness == "down"


def test_federate_all_registers_every_modality_profile():
    edge = Orchestrator()
    edge.register(MemristiveAdapter("edge-crossbar"))
    edge.register(ChemicalAdapter())
    gw = ControlPlaneGateway(edge, plane="lab").start()
    cloud = Orchestrator()
    try:
        adapters = federate_all(cloud, gw.url)
        assert len(adapters) == 2      # vector->vector + conc->conc profiles
        rids = {a.resource_id for a in adapters}
        assert rids == {"plane-lab-vector-vector",
                        "plane-lab-concentration-concentration"}
        # the chemical profile is reachable through its own plane adapter
        res, trace = cloud.submit(TaskRequest(
            function="assay", input_modality="concentration",
            output_modality="concentration",
            payload={"concentrations": [0.1, 0.7, 0.1, 0.1]},
            required_telemetry=("convergence_ms",)))
        assert res.status == "completed"
        assert trace.selected == "plane-lab-concentration-concentration"
        assert res.telemetry["remote_resource_id"] == "chemical-ode"
    finally:
        gw.stop()


def test_forwarded_task_strips_plane_local_directives(edge_plane):
    """backend_preference names a CLOUD resource; forwarding it verbatim
    would make the edge matcher reject — the adapter must strip it (and
    twin_mode, which the parent owns)."""
    _, gw = edge_plane
    cloud = _cloud()
    adapter = federate(cloud, gw.url)
    res, _ = cloud.submit(_vector_task(
        backend_preference=adapter.resource_id, twin_mode="fallback"))
    assert res.status == "completed"
    assert res.telemetry["remote_resource_id"] in EDGE_SUBSTRATES
    assert res.telemetry.get("served_by") != "twin"
