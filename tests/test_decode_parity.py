"""Decode/forward parity: the KV-cache decode path must produce the same
logits as the full train-mode forward on the same token prefix.

This is the strongest correctness test of the serving substrate — it
exercises cache layout, ring buffers, RoPE absolute positions, recurrent
state carry-over and the MLA absorbed-decode reformulation all at once.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import (build_decode_step, build_prefill_step, decode_cache,
                          model_specs)
from repro.models import common as cm
from repro.models.model import _decoder, _encoder, _logit_kernel, _sinusoid, _embed_tokens
from repro.models.common import init_params
from repro.serving.cache_utils import extend_cache

pytestmark = pytest.mark.slow    # heavy suite: excluded from make test-fast

# fp32 reduced configs keep the comparison numerically clean
PARITY_ARCHS = ["internlm2-20b", "qwen2.5-32b", "command-r-35b",
                "recurrentgemma-9b", "rwkv6-7b", "deepseek-v2-236b",
                "moonshot-v1-16b-a3b", "whisper-large-v3",
                "llama-3.2-vision-90b", "nemotron-4-340b"]


def full_forward_logits(cfg, params, batch):
    """Train-path forward returning (B, S, V) logits (small V, fine)."""
    tokens = batch["tokens"]
    S = tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x = _embed_tokens(cfg, params, tokens)
    ctx = None
    if cfg.family == "encdec":
        enc_x = batch["frames"].astype(x.dtype)
        enc_pos = jnp.arange(enc_x.shape[1], dtype=jnp.int32)
        enc_x = enc_x + _sinusoid(enc_pos, cfg.d_model).astype(x.dtype)
        ctx, _ = _encoder(cfg).train(params["encoder"], enc_x, enc_pos)
        ctx = cm.apply_norm(cfg, params["enc_norm"], ctx)
        x = x + _sinusoid(positions, cfg.d_model).astype(x.dtype)
    elif cfg.family == "vision":
        ctx = batch["image_embeds"].astype(x.dtype)
    feats, _ = _decoder(cfg).train(params["decoder"], x, positions, ctx)
    feats = cm.apply_norm(cfg, params["final_norm"], feats)
    return jnp.einsum("bsd,dv->bsv", feats,
                      _logit_kernel(cfg, params)).astype(jnp.float32)


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = reduced(get_config(arch))
    # window smaller than total length would need ring-roll handling in the
    # test; keep total below the reduced window (16) + prompt
    total, prompt_len = 12, 6
    params = init_params(model_specs(cfg), seed=1)
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, total)),
                         jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(2, cfg.encoder_frames, cfg.d_model)), jnp.float32)
    if cfg.family == "vision":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(2, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)

    ref_logits = full_forward_logits(cfg, params, batch)      # (B, total, V)

    # prefill on the prompt, then decode the remaining tokens one by one
    pre_batch = dict(batch, tokens=tokens[:, :prompt_len])
    cache, logits_p = jax.jit(build_prefill_step(cfg))(params, pre_batch)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(ref_logits[:, prompt_len - 1]),
                               rtol=2e-3, atol=2e-3)

    dcache = decode_cache(cfg, 2, total)
    dcache = extend_cache(dcache, cache, prompt_len)
    decode = jax.jit(build_decode_step(cfg))
    for pos in range(prompt_len, total):
        dcache, logits_d = decode(params, dcache, tokens[:, pos:pos + 1],
                                  jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(ref_logits[:, pos]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch} decode diverges at pos {pos}")
