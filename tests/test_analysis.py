"""planelint: falsifiability tests for every static rule + the runtime witness.

Each checker must (a) catch a deliberately violating fixture and (b) pass
the fixed twin of the same fixture — a rule that cannot fail is not a
rule.  The witness tests prove an injected ABBA interleaving is reported
deterministically, and the sim/chaos-marked tests run the PR 8 scenario
matrix and concurrent fault campaign under the witness, so the 1000-plane
simulator doubles as a deadlock fuzzer.
"""
import textwrap
import threading
from pathlib import Path

import pytest

from repro.analysis import all_checkers, apply_pragmas, load_project, run_checkers
from repro.analysis.checkers.clock_seam import ClockSeamChecker
from repro.analysis.checkers.codec_drift import CodecDriftChecker
from repro.analysis.checkers.error_taxonomy import ErrorTaxonomyChecker
from repro.analysis.checkers.guarded_by import GuardedByChecker
from repro.analysis.checkers.lock_order import (LockOrderChecker,
                                                build_lock_graph,
                                                render_graph, _find_cycles)
from repro.analysis.witness import (LockWitness, WitnessViolation,
                                    witnessed_locks)

REPO_ROOT = Path(__file__).resolve().parents[1]


def _fixture(tmp_path: Path, files: dict) -> Path:
    """Write {relpath: source} under tmp_path/src/repro and return tmp_path."""
    for rel, src in files.items():
        path = tmp_path / "src" / "repro" / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(textwrap.dedent(src), encoding="utf-8")
    return tmp_path


def _run(checker, root: Path):
    return checker.check(load_project(root))


# -- clock-seam -------------------------------------------------------------

BAD_CLOCK = """
    import time
    from dataclasses import dataclass, field


    def stamp():
        return time.time()


    def wait(dt, ts=time.monotonic()):
        time.sleep(dt)


    @dataclass
    class Snap:
        at: float = field(default_factory=time.time)
"""

GOOD_CLOCK = """
    from typing import Optional


    def stamp(now: Optional[float] = None):
        return now


    def wait(clock, dt):
        clock.sleep(dt)
"""


def test_clock_seam_catches_violations_and_passes_fixed_twin(tmp_path):
    root = _fixture(tmp_path, {"core/mod.py": BAD_CLOCK,
                               "core/fixed.py": GOOD_CLOCK})
    findings = _run(ClockSeamChecker(), root)
    assert len(findings) == 4        # time.time, param default, sleep, factory
    assert all(f.rule == "clock-seam" for f in findings)
    assert all(f.path == "src/repro/core/mod.py" for f in findings)
    assert all(f.hint for f in findings)

    fixed = _fixture(tmp_path / "fixed", {"core/mod.py": GOOD_CLOCK})
    assert _run(ClockSeamChecker(), fixed) == []


def test_clock_seam_ignores_out_of_scope_modules(tmp_path):
    root = _fixture(tmp_path, {"kernels/mod.py": BAD_CLOCK})
    assert _run(ClockSeamChecker(), root) == []


def test_pragma_suppresses_same_line_and_next_line(tmp_path):
    root = _fixture(tmp_path, {"core/mod.py": """
        import time


        def a():
            return time.time()  # planelint: allow(clock-seam) — test wants wall

        def b():
            # planelint: allow(clock-seam) — comment-only form covers next line
            return time.time()

        def c():
            return time.time()
    """})
    project = load_project(root)
    raw = ClockSeamChecker().check(project)
    assert len(raw) == 3
    kept, suppressed = apply_pragmas(project, raw)
    assert suppressed == 2
    assert len(kept) == 1 and kept[0].line > 10


def test_allow_file_pragma_suppresses_whole_module(tmp_path):
    root = _fixture(tmp_path, {"core/mod.py": """
        # planelint: allow-file(clock-seam) — fixture-wide waiver
        import time


        def a():
            return time.time()
    """})
    project = load_project(root)
    kept, suppressed = apply_pragmas(project, ClockSeamChecker().check(project))
    assert kept == [] and suppressed == 1


# -- lock-order -------------------------------------------------------------

ABBA = """
    import threading


    class S:
        def __init__(self):
            self._a = threading.Lock()
            self._b = threading.Lock()

        def one(self):
            with self._a:
                with self._b:
                    pass

        def two(self):
            with self._b:
                with self._a:
                    pass
"""

ABBA_FIXED = ABBA.replace("with self._b:\n                with self._a:",
                          "with self._a:\n                with self._b:")


def _errors(findings):
    return [f for f in findings if f.severity == "error"]


def test_lock_order_catches_abba_cycle_and_passes_fixed_twin(tmp_path):
    findings = _errors(_run(LockOrderChecker(),
                            _fixture(tmp_path, {"core/mod.py": ABBA})))
    assert len(findings) == 1
    assert "cycle" in findings[0].message
    assert "S._a" in findings[0].message and "S._b" in findings[0].message

    fixed = _fixture(tmp_path / "fixed", {"core/mod.py": ABBA_FIXED})
    assert _errors(_run(LockOrderChecker(), fixed)) == []


def test_lock_order_catches_self_reacquire_of_plain_lock(tmp_path):
    bad = """
        import threading


        class S:
            def __init__(self):
                self._a = threading.Lock()

            def outer(self):
                with self._a:
                    self.inner()

            def inner(self):
                with self._a:
                    pass
    """
    findings = _errors(_run(LockOrderChecker(),
                            _fixture(tmp_path, {"core/mod.py": bad})))
    assert findings and all("self-deadlock" in f.message for f in findings)

    fixed = _fixture(tmp_path / "fixed", {
        "core/mod.py": bad.replace("threading.Lock()", "threading.RLock()")})
    assert _errors(_run(LockOrderChecker(), fixed)) == []


def test_repo_lock_graph_is_acyclic_and_matches_golden():
    """Regression for the committed golden: the real control plane's static
    lock graph stays acyclic and exactly matches analysis/lock_order.golden
    (new edges must be reviewed + regenerated, never drift in silently)."""
    project = load_project(REPO_ROOT)
    _model, adj, _sites = build_lock_graph(project)
    assert _find_cycles(adj) == []
    golden_path = REPO_ROOT / "src/repro/analysis/lock_order.golden"
    assert golden_path.exists()
    golden = [ln.strip() for ln in golden_path.read_text().splitlines()
              if ln.strip() and not ln.startswith("#")]
    assert render_graph(adj) == golden


# -- guarded-by -------------------------------------------------------------

GUARDED_BAD = """
    import threading


    class Box:
        def __init__(self):
            self._lock = threading.Lock()
            self._count = 0   # guarded_by: _lock

        def good(self):
            with self._lock:
                self._count += 1

        def bad(self):
            return self._count
"""


def test_guarded_by_catches_unlocked_access_and_passes_fixed_twin(tmp_path):
    findings = _run(GuardedByChecker(),
                    _fixture(tmp_path, {"core/mod.py": GUARDED_BAD}))
    assert len(findings) == 1
    assert "read without holding Box._lock" in findings[0].message
    assert findings[0].line == 15

    fixed_src = GUARDED_BAD.replace(
        "return self._count",
        "with self._lock:\n                return self._count")
    fixed = _fixture(tmp_path / "fixed", {"core/mod.py": fixed_src})
    assert _run(GuardedByChecker(), fixed) == []


def test_guarded_by_trusts_holds_pragma_and_condition_alias(tmp_path):
    src = """
        import threading


        class Box:
            def __init__(self):
                self._lock = threading.Lock()
                self._cond = threading.Condition(self._lock)
                self._count = 0   # guarded_by: _lock

            def via_condition(self):
                with self._cond:
                    self._count += 1

            def helper(self):  # planelint: holds(_lock)
                self._count += 1
    """
    assert _run(GuardedByChecker(),
                _fixture(tmp_path, {"core/mod.py": src})) == []


# -- error-taxonomy ---------------------------------------------------------

ERRORS_MOD = """
    _CLASSIFIERS = (
        ("queue full", "QUEUE_SATURATED"),
        ("deadline", "DEADLINE"),
    )
"""

TAXONOMY_BAD = """
    class Scheduler:
        def reject_paths(self, task, trace, inv):
            raise ControlPlaneError("oops", code="queue_saturated")

        def mint(self, task):
            return InvocationResult(task_id=task.task_id, status="rejected")

        def funnel(self, inv, task):
            return inv.rejected(task, "mystery wording nobody classifies")
"""

TAXONOMY_FIXED = """
    class Scheduler:
        def reject_paths(self, task, trace, inv):
            raise ControlPlaneError("oops", code=ErrorCode.QUEUE_SATURATED)

        def funnel(self, inv, task):
            return inv.rejected(task, "queue full right now")

        def funnel2(self, inv, task, why):
            return inv.rejected(task, f"dynamic: {why}")

        def funnel3(self, inv, task):
            return inv.rejected(task, "mystery wording", code=ErrorCode.INTERNAL)
"""


def test_error_taxonomy_catches_all_three_rules_and_passes_fixed_twin(tmp_path):
    root = _fixture(tmp_path, {"core/errors.py": ERRORS_MOD,
                               "core/scheduler.py": TAXONOMY_BAD})
    findings = _run(ErrorTaxonomyChecker(), root)
    messages = " | ".join(f.message for f in findings)
    assert len(findings) == 3
    assert "bare string code" in messages              # R1
    assert "bypasses the error_code funnel" in messages  # R2
    assert "matches no" in messages                    # R3

    fixed = _fixture(tmp_path / "fixed", {"core/errors.py": ERRORS_MOD,
                                          "core/scheduler.py": TAXONOMY_FIXED})
    assert _run(ErrorTaxonomyChecker(), fixed) == []


# -- codec-drift ------------------------------------------------------------

def test_codec_drift_catches_duplicate_and_reorder(tmp_path):
    root = _fixture(tmp_path, {"gateway/protocol.py": """
        INTERNED_FIELDS = ("kind", "body", "kind")
    """})
    golden = tmp_path / "src/repro/analysis/codec_fields.golden"
    golden.parent.mkdir(parents=True, exist_ok=True)
    golden.write_text("[interned]\nkind\nstatus\nbody\n[exempt]\nkind\nbody\nstatus\n")
    findings = _errors(_run(CodecDriftChecker(), root))
    messages = " | ".join(f.message for f in findings)
    assert "duplicate interned field 'kind'" in messages
    assert "no longer a prefix-extension" in messages


def test_codec_drift_appended_entries_warn_until_golden_regenerated(tmp_path):
    root = _fixture(tmp_path, {"gateway/protocol.py": """
        INTERNED_FIELDS = ("kind", "body", "fresh")
    """})
    golden = tmp_path / "src/repro/analysis/codec_fields.golden"
    golden.parent.mkdir(parents=True, exist_ok=True)
    golden.write_text("[interned]\nkind\nbody\n[exempt]\nkind\nbody\nfresh\n")
    findings = _run(CodecDriftChecker(), root)
    assert _errors(findings) == []
    warns = [f for f in findings if f.severity == "warn"]
    assert len(warns) == 1 and "appended beyond the golden: fresh" in warns[0].message

    # regenerating the golden absorbs the appended entry and preserves exempt
    CodecDriftChecker().update_goldens(load_project(root))
    assert _run(CodecDriftChecker(), root) == []


def test_codec_drift_catches_uninterned_wire_field(tmp_path):
    root = _fixture(tmp_path, {
        "gateway/protocol.py": """
            INTERNED_FIELDS = ("kind",)

            def encode(env):
                return {"kind": env.kind, "payload": env.payload}
        """})
    golden = tmp_path / "src/repro/analysis/codec_fields.golden"
    golden.parent.mkdir(parents=True, exist_ok=True)
    golden.write_text("[interned]\nkind\n[exempt]\n")
    findings = _errors(_run(CodecDriftChecker(), root))
    assert len(findings) == 1
    assert "wire field 'payload'" in findings[0].message


# -- whole-repo gate --------------------------------------------------------

def test_repo_is_strict_clean_under_all_checkers():
    """The acceptance gate CI runs: zero errors AND zero warnings on the
    real repo across all five rules (pragma-suppressed findings allowed)."""
    project = load_project(REPO_ROOT)
    findings, _suppressed = run_checkers(project, all_checkers())
    assert findings == [], "\n".join(f.format() for f in findings)


def test_cli_strict_exits_zero_on_repo():
    from repro.analysis.__main__ import main
    assert main(["--strict"]) == 0


def test_cli_rejects_unknown_rule():
    from repro.analysis.__main__ import main
    with pytest.raises(SystemExit):
        main(["--rule", "no-such-rule"])


# -- runtime witness --------------------------------------------------------

def _run_abba_once() -> LockWitness:
    """Deterministically interleave an ABBA acquisition with events: T1
    takes A then attempts B; T2 takes B then attempts A.  Timeouts keep
    the test from deadlocking — the ORDER edges are recorded at attempt
    time, so the cycle is witnessed either way."""
    with witnessed_locks() as w:
        lock_a = threading.Lock()
        lock_b = threading.Lock()
    t1_has_a = threading.Event()
    t2_has_b = threading.Event()

    def t1():
        with lock_a:
            t1_has_a.set()
            t2_has_b.wait(timeout=5)
            if lock_b.acquire(timeout=0.05):
                lock_b.release()

    def t2():
        t1_has_a.wait(timeout=5)
        with lock_b:
            t2_has_b.set()
            if lock_a.acquire(timeout=0.5):
                lock_a.release()

    threads = [threading.Thread(target=t1), threading.Thread(target=t2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return w


def test_witness_reports_injected_abba_deterministically():
    first = _run_abba_once().report()
    second = _run_abba_once().report()
    assert len(first["cycles"]) == 1
    assert len(first["cycles"][0]) == 2
    with pytest.raises(WitnessViolation, match="lock-order cycle"):
        _run_abba_once().assert_clean()
    # byte-identical across runs: no timestamps, sites not instances
    assert first == second


def test_witness_consistent_order_is_clean():
    with witnessed_locks() as w:
        lock_a = threading.Lock()
        lock_b = threading.Lock()

    def worker():
        for _ in range(50):
            with lock_a:
                with lock_b:
                    pass

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(w.edges()) == 1
    w.assert_clean()


def test_witness_flags_self_reacquire_of_plain_lock():
    with witnessed_locks() as w:
        lock = threading.Lock()
        rlock = threading.RLock()
    with lock:
        assert not lock.acquire(timeout=0.01)   # recorded before blocking
    with rlock:
        with rlock:                             # reentrant: fine
            pass
    assert any("self-reacquire" in v for v in w.violations())
    assert len(w.violations()) == 1


def test_witness_flags_hold_while_blocking_on_condition():
    with witnessed_locks() as w:
        outer = threading.Lock()
        cond = threading.Condition(threading.Lock())

    def bad():
        with outer:
            with cond:
                cond.wait(timeout=0.01)

    t = threading.Thread(target=bad)
    t.start()
    t.join()
    assert any("hold-while-blocking" in v for v in w.violations())
    with pytest.raises(WitnessViolation, match="hold-while-blocking"):
        w.assert_clean()


def test_witness_condition_wait_for_round_trip_is_clean():
    with witnessed_locks() as w:
        cond = threading.Condition(threading.Lock())
        done = []

    def waiter():
        with cond:
            assert cond.wait_for(lambda: done, timeout=5)

    def setter():
        with cond:
            done.append(1)
            cond.notify_all()

    threads = [threading.Thread(target=waiter), threading.Thread(target=setter)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    w.assert_clean()


# -- witness under the PR 8 scenario matrix / chaos campaign ----------------

@pytest.mark.sim
def test_witness_clean_under_scenario_matrix():
    """The virtual-time fleet simulator doubles as a deadlock fuzzer: every
    scenario builder runs under the witness and the observed acquisition
    graph must stay acyclic with no blocking violations."""
    from repro.core.simulator import FleetSimulator, scenario_matrix

    with witnessed_locks() as w:
        for sc in scenario_matrix(planes=20, substrates_per_plane=4,
                                  duration_s=120.0):
            report = FleetSimulator(sc, seed=11).run()
            assert report["real_sleep_calls"] == 0
    assert w.report()["locks"] > 100
    w.assert_clean()


@pytest.mark.chaos
def test_witness_clean_under_concurrent_chaos_campaign():
    """Real threads, real locks: the full concurrent fault campaign runs
    with every control-plane lock witnessed.  This covers the static
    checker's known blind spot (opaque clock/subscriber callables)."""
    from repro.core import Orchestrator, TaskRequest
    from repro.core.faults import (build_concurrent_campaign,
                                   run_campaign_concurrent)
    from repro.substrates import standard_testbed

    def _task(i):
        return TaskRequest(function="inference", input_modality="vector",
                           output_modality="vector",
                           payload=[0.2, 0.4, 0.1, 0.3])

    with witnessed_locks() as w:
        orch = Orchestrator(health={"cooldown_s": 0.2, "probes_to_close": 2})
        standard_testbed(orch)
        report = run_campaign_concurrent(
            orch, build_concurrent_campaign(), workers=8,
            load_template=_task, load_tasks=24)
        assert report["all_pass"], \
            [r for r in report["rows"] if not r["pass"]]
    assert w.report()["locks"] > 50
    w.assert_clean()
