"""Sharding recipe resolution + loop-aware HLO analyzer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (BASELINE, RECIPES, cache_spec,
                                        for_decode, spec_for_axes)
from repro.launch.mesh import make_smoke_mesh
from repro.roofline.hlo import analyze, parse_module

pytestmark = pytest.mark.slow    # heavy suite: excluded from make test-fast


@pytest.fixture(scope="module")
def mesh22():
    # 1 real CPU device can't make a 2x2 mesh; emulate axis sizes via a
    # Mesh over reshaped device list is impossible — use abstract mesh.
    from jax.sharding import AbstractMesh
    return AbstractMesh((2, 2), ("data", "model"))


def test_divisibility_fallback(mesh22):
    # 8 kv heads over 2-way model axis: fine; 3 heads: replicated
    assert spec_for_axes(("kv_heads",), BASELINE, mesh22, (8,)) == P("model")
    assert spec_for_axes(("kv_heads",), BASELINE, mesh22, (3,)) == P(None)


def test_axis_dedup_within_tensor(mesh22):
    # ("embed", "embed") may not reuse the data axis twice
    spec = spec_for_axes(("embed", "embed"), BASELINE, mesh22, (8, 8))
    assert spec == P("data", None)


def test_batch_then_seq_priority_in_cache(mesh22):
    # kv_heads grabs model first; seq_kv only gets leftovers
    spec = cache_spec("k", (8, 64, 8, 16), BASELINE, mesh22)
    assert spec == P("data", None, "model", None)
    # kv=1 (MQA): model axis falls through to the sequence dim
    spec = cache_spec("k", (8, 64, 1, 16), BASELINE, mesh22)
    assert spec == P("data", "model", None, None)


def test_for_decode_extends_batch(mesh22):
    r = for_decode(BASELINE)
    assert r.rules["batch"][-1] == "model"
    spec = cache_spec("s", (8, 4, 16, 16), r, mesh22)
    assert spec[0] in (("data", "model"), "data")


def test_all_recipes_resolve_all_axes(mesh22):
    for name, r in RECIPES.items():
        for ax in ("batch", "vocab", "heads", "mlp", "embed", "expert"):
            spec_for_axes((ax,), r, mesh22, (64,))  # must not raise


# ---------------------------------------------------------------------------
# loop-aware HLO analyzer


SYNTH_HLO = """
HloModule synth

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%d), replica_groups=[2,2]<=[4], to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_analyzer_multiplies_loop_trips():
    r = analyze(SYNTH_HLO)
    # dot: 2*8*8*8 = 1024 flops, ×10 trips
    assert r["flops"] == pytest.approx(1024 * 10)
    # all-reduce: 8*8*4 bytes, ring 2×(g-1)/g with g=2 → ×1.0, ×10 trips
    assert r["collectives"]["all-reduce"] == pytest.approx(256 * 10)
    assert r["collectives"]["counts"]["all-reduce"] == 10


def test_analyzer_on_real_lowered_scan():
    """A jitted scan of matmuls must count body flops × length."""
    n, d, L = 4, 16, 7

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    x = jnp.ones((n, d))
    ws = jnp.ones((L, d, d))
    txt = jax.jit(f).lower(x, ws).compile().as_text()
    r = analyze(txt)
    expect = 2 * n * d * d * L
    assert r["flops"] >= expect * 0.99, (r["flops"], expect)
    assert r["flops"] <= expect * 1.5, (r["flops"], expect)


def test_parse_module_structure():
    comps, entry = parse_module(SYNTH_HLO)
    assert entry == "main"
    assert "body" in comps and "cond" in comps
    kinds = [o.kind for o in comps["body"].ops]
    assert "dot" in kinds and "all-reduce" in kinds
