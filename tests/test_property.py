"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import Orchestrator, TaskRequest
from repro.core.descriptors import shared_key_ratio
from repro.core.matcher import Matcher
from repro.core.telemetry import RuntimeSnapshot
from repro.models.common import rmsnorm, layernorm, rope
from repro.roofline.analysis import roofline_terms
from repro.substrates import MemristiveAdapter

jax.config.update("jax_platforms", "cpu")


@settings(max_examples=25, deadline=None)
@given(drift=st.floats(0.0, 0.49), drift_hi=st.floats(0.5, 1.0))
def test_matcher_score_monotone_in_drift(drift, drift_hi):
    """More drift must never raise a backend's score (Eq. 1 D-term)."""
    orch = Orchestrator()
    orch.register(MemristiveAdapter())
    task = TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector")
    m = orch.matcher

    def score_at(d):
        orch.bus.update_snapshot(RuntimeSnapshot("memristive-local",
                                                 drift_score=d))
        c = m.score(orch.registry.get("memristive-local"), task)
        return c.score if c.admissible else float("-inf")

    assert score_at(drift) >= score_at(drift_hi)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.dictionaries(st.sampled_from("abcdef"), st.integers(),
                                min_size=1, max_size=6), min_size=1,
                max_size=5))
def test_shared_key_ratio_bounds(dicts):
    r = shared_key_ratio(dicts)
    assert 0.0 <= r <= 1.0
    if all(set(d) == set(dicts[0]) for d in dicts):
        assert r == 1.0


@settings(max_examples=20, deadline=None)
@given(flops=st.floats(1e6, 1e18), byts=st.floats(1e3, 1e15),
       coll=st.floats(0, 1e14))
def test_roofline_terms_invariants(flops, byts, coll):
    t = roofline_terms(flops, byts, coll)
    assert t["step_time_lb_s"] == pytest.approx(
        max(t["compute_s"], t["memory_s"], t["collective_s"]))
    assert 0.0 <= t["roofline_fraction"] <= 1.0 + 1e-9
    assert t["dominant"] in ("compute", "memory", "collective")
    # the dominant term is the bound
    assert t[t["dominant"] + "_s"] == pytest.approx(t["step_time_lb_s"])


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(2, 32), st.integers(2, 64))
def test_rmsnorm_scale_invariance_property(b, s, d):
    """rmsnorm(αx) == rmsnorm(x) for α>0 (scale invariance)."""
    rng = np.random.default_rng(b * 1000 + s * 10 + d)
    x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    w = jnp.zeros((d,), jnp.float32)
    y1 = rmsnorm(x, w)
    y2 = rmsnorm(3.7 * x, w)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 16), st.integers(8, 64))
def test_rope_preserves_norm_property(s, hd):
    hd = hd - hd % 2
    rng = np.random.default_rng(s * 100 + hd)
    x = jnp.asarray(rng.normal(size=(1, s, 2, hd)), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    y = rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 200))
def test_data_pipeline_deterministic_property(seed, step):
    from repro.training.data import SyntheticTokenDataset

    d1 = SyntheticTokenDataset(997, 8, 2, seed=seed)
    d2 = SyntheticTokenDataset(997, 8, 2, seed=seed)
    b1, b2 = d1.batch_at(step), d2.batch_at(step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # disjoint host shards differ
    d3 = SyntheticTokenDataset(997, 8, 2, seed=seed, host_id=1, num_hosts=2)
    assert not np.array_equal(d3.batch_at(step)["tokens"], b1["tokens"])


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.integers(1, 4))
def test_checkpoint_roundtrip_property(depth, width):
    import tempfile
    from repro.training.checkpoint import CheckpointManager

    rng = np.random.default_rng(depth * 7 + width)
    tree = {}
    node = tree
    for i in range(depth):
        node[f"level{i}"] = {"w": rng.normal(size=(width, width)).astype(
            np.float32)}
        node = node[f"level{i}"]
    with tempfile.TemporaryDirectory() as td:
        cm = CheckpointManager(td)
        cm.save(1, tree)
        restored, meta = cm.restore(tree)
        for (pa, a), (pb, b) in zip(
                jax.tree_util.tree_flatten_with_path(tree)[0],
                jax.tree_util.tree_flatten_with_path(restored)[0]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
