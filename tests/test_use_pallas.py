"""use_pallas routes models through the Pallas kernels (interpret=True on
CPU) — losses must match the pure-JAX path bit-for-bit-ish."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import loss_fn, model_specs
from repro.models.common import init_params

pytestmark = pytest.mark.slow    # heavy suite: excluded from make test-fast


@pytest.mark.parametrize("arch", ["internlm2-20b", "rwkv6-7b",
                                  "recurrentgemma-9b", "qwen2.5-32b"])
def test_pallas_path_matches_reference(arch):
    cfg0 = reduced(get_config(arch), vocab_size=128, attn_chunk=64)
    layers = 3 if arch == "recurrentgemma-9b" else 2
    cfg0 = dataclasses.replace(cfg0, num_layers=layers)
    cfg1 = dataclasses.replace(cfg0, use_pallas=True)
    params = init_params(model_specs(cfg0), seed=2)
    rng = np.random.default_rng(1)
    B, S = 2, 64
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg0.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg0.vocab_size, (B, S)),
                                   jnp.int32)}
    l0, _ = jax.jit(lambda p, b: loss_fn(cfg0, p, b))(params, batch)
    l1, _ = jax.jit(lambda p, b: loss_fn(cfg1, p, b))(params, batch)
    assert abs(float(l0) - float(l1)) < 5e-3, (arch, float(l0), float(l1))


def test_pallas_grads_match_reference():
    cfg0 = reduced(get_config("internlm2-20b"), vocab_size=64, num_layers=2,
                   attn_chunk=64)
    cfg1 = dataclasses.replace(cfg0, use_pallas=True)
    params = init_params(model_specs(cfg0), seed=5)
    rng = np.random.default_rng(3)
    batch = {"tokens": jnp.asarray(rng.integers(0, 64, (2, 64)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 64, (2, 64)), jnp.int32)}
    g0 = jax.grad(lambda p: loss_fn(cfg0, p, batch)[0])(params)
    g1 = jax.grad(lambda p: loss_fn(cfg1, p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-4)
