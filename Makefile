# phys-MCP reproduction — reproducible verify + benchmark entry points.
#
#   make test              tier-1 verify (the ROADMAP.md command)
#   make test-fast         everything not marked slow (control plane,
#                          chaos, health; ~20s, no kernel/model suites)
#   make chaos-smoke       ~30s concurrent mini-campaign: recovery bench
#                          (1 quick trial) + full chaos scenario matrix
#   make test-twin         executable-twin suites: fidelity/parity,
#                          executor (shadow/fallback/speculate), properties
#   make twin-smoke        quick twin-fallback goodput trial + validity audit
#   make test-gateway      wire-layer suites: protocol round-trips (both
#                          codecs), gateway endpoint/error-taxonomy e2e,
#                          federated planes, streaming telemetry,
#                          multi-hop topology, coalesced wire path
#   make gateway-smoke     ~20s wire round-trip (discover→invoke→telemetry
#                          on the mixed testbed) + 1 overhead trial per
#                          codec, asserting the p50 wire-excess budget
#   make bench-gateway-smoke  alias for gateway-smoke (budget-asserting
#                          quick trial, for CI)
#   make hierarchy-smoke   ~60s 3-tier drill: 4-plane chain per-hop cost,
#                          stream-vs-poll fan-in, kill-the-middle-plane
#                          breaker + twin-fallback verification
#   make bench-gateway     local vs wire control-path overhead per codec
#                          (asserts median wire excess p50 <= 1 ms) + the
#                          connection-churn capacity sweep (async gateway
#                          must sustain >= 10x the threaded baseline)
#   make bench-hierarchy   multi-hop chain + streaming fan-in benchmark
#                          (per-hop added latency <= single-hop margin,
#                          >= 2x fewer requests than cursor polling)
#   make serving-smoke     LM serving drill: engine/adapter + paged-KV
#                          suites (allocator properties, paged/contiguous
#                          parity), quick continuous-batching + paged
#                          trials and a 16-session gateway flood
#                          (structured DEADLINE/QUEUE_SATURATED refusals,
#                          zero mid-decode expiries, zero page leaks)
#   make bench-serving     full LM serving benchmark: continuous vs fixed
#                          batch goodput on a mixed-length trace (asserts
#                          >= 2x), paged-KV parity/capacity/prefix gates
#                          (>= 1x goodput, 2x capacity, >= 30% TTFT cut)
#                          + 128 concurrent gateway sessions (bounded
#                          p99 TTFT, admission refusals)
#   make test-sim          virtual-time suites: clock semantics, scheduler
#                          timebase regressions, simulator invariants
#   make sim-smoke         CI-sized scenario matrix: >=100 planes on pure
#                          virtual time, all invariant audits, <60s
#   make bench-scenarios   full planet-scale scenario harness: 6 scenarios
#                          x 1000 planes x 10k substrates, 1 simulated
#                          hour each, zero violations + determinism check
#   make bench-throughput  headline serial-vs-pooled scheduler benchmark
#   make bench-recovery    resilience benchmark: goodput under faults with
#                          vs without the HealthManager
#   make bench-twin        twin-fallback vs reject-only goodput benchmark
#   make bench             full benchmark harness (all paper tables)
#   make lint-plane        planelint --strict (five control-plane invariant
#                          checkers + pinned goldens), then ruff when
#                          installed
#   make dev-deps          install dev/test dependencies

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast chaos-smoke test-twin twin-smoke test-gateway \
        gateway-smoke bench-gateway-smoke hierarchy-smoke serving-smoke \
        test-sim sim-smoke bench-scenarios \
        bench bench-throughput bench-recovery bench-twin bench-gateway \
        bench-hierarchy bench-serving lint-plane dev-deps

test:
	$(PYTHON) -m pytest -x -q

test-fast:
	$(PYTHON) -m pytest -q -m "not slow"

chaos-smoke:
	$(PYTHON) -m benchmarks.bench_recovery --smoke

test-twin:
	$(PYTHON) -m pytest -q tests/test_twin_fidelity.py \
	    tests/test_twin_executor.py tests/test_twin_property.py

twin-smoke:
	$(PYTHON) -m benchmarks.bench_twin --smoke

test-gateway:
	$(PYTHON) -m pytest -q tests/test_protocol.py tests/test_codec.py \
	    tests/test_gateway.py tests/test_federation.py tests/test_stream.py \
	    tests/test_topology.py tests/test_wirepath.py

gateway-smoke:
	$(PYTHON) -m benchmarks.bench_gateway --smoke

bench-gateway-smoke: gateway-smoke

hierarchy-smoke:
	$(PYTHON) -m benchmarks.bench_hierarchy --smoke

serving-smoke:
	$(PYTHON) -m pytest -q tests/test_serving.py tests/test_kv_pages.py \
		tests/test_serving_paged.py -m "not slow"
	$(PYTHON) -m benchmarks.bench_serving --smoke

bench-serving:
	$(PYTHON) -m benchmarks.bench_serving

test-sim:
	$(PYTHON) -m pytest -q -m sim

sim-smoke:
	$(PYTHON) -m pytest -q -m sim
	$(PYTHON) -m benchmarks.bench_scenarios --smoke

bench-scenarios:
	$(PYTHON) -m benchmarks.bench_scenarios

bench-gateway:
	$(PYTHON) -m benchmarks.bench_gateway

bench-hierarchy:
	$(PYTHON) -m benchmarks.bench_hierarchy

bench-throughput:
	$(PYTHON) -m benchmarks.bench_throughput

bench-recovery:
	$(PYTHON) -m benchmarks.bench_recovery

bench-twin:
	$(PYTHON) -m benchmarks.bench_twin

bench:
	$(PYTHON) -m benchmarks.run

lint-plane:
	$(PYTHON) -m repro.analysis --strict
	@if command -v ruff >/dev/null 2>&1; then \
	    ruff check .; \
	else \
	    echo "ruff not installed; skipping (make dev-deps to get it)"; \
	fi

dev-deps:
	$(PYTHON) -m pip install -r requirements-dev.txt
