# phys-MCP reproduction — reproducible verify + benchmark entry points.
#
#   make test              tier-1 verify (the ROADMAP.md command)
#   make test-fast         control-plane tests only (seconds, no kernels)
#   make bench-throughput  headline serial-vs-pooled scheduler benchmark
#   make bench             full benchmark harness (all paper tables)
#   make dev-deps          install dev/test dependencies

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench bench-throughput dev-deps

test:
	$(PYTHON) -m pytest -x -q

test-fast:
	$(PYTHON) -m pytest -q tests/test_system.py tests/test_matcher.py \
	    tests/test_faults.py tests/test_lifecycle_contracts.py \
	    tests/test_scheduler_concurrency.py \
	    tests/test_orchestrator_accounting.py

bench-throughput:
	$(PYTHON) -m benchmarks.bench_throughput

bench:
	$(PYTHON) -m benchmarks.run

dev-deps:
	$(PYTHON) -m pip install -r requirements-dev.txt
