"""Planet-scale scenario harness benchmark: the full six-scenario matrix
at 1000 planes / 10,000 substrates, entirely on virtual time.

Per trial the matrix (diurnal wave, flash crowd, regional partition,
cascading breaker storm, twin-fidelity collapse, rolling protocol
upgrade) simulates ONE HOUR of fleet behavior per scenario.  Reported
per scenario: tasks driven, trace events, wall seconds, and the
virtual-time speedup (simulated seconds per wall second).  Asserted per
scenario:

- ZERO invariant violations (breaker legality/continuity, twin-serve
  validity, exact budget arithmetic, slot balance, session uniqueness);
- ZERO real ``time.sleep`` calls on the simulated path (the run executes
  under ``forbid_real_sleep``);
- same seed ⇒ identical event-trace hash (re-run of the first scenario).

    PYTHONPATH=src python -m benchmarks.bench_scenarios [--smoke]
"""
from __future__ import annotations

import statistics
from typing import List, Optional

from benchmarks.common import csv_row, save

PLANES = 1000
SUBSTRATES_PER_PLANE = 10
DURATION_S = 3600.0          # one simulated hour per scenario
N_TRIALS = 3
BASE_SEED = 1009


def _run_matrix(planes: int, substrates: int, duration_s: float,
                seed: int) -> List[dict]:
    from repro.core.simulator import FleetSimulator, scenario_matrix

    reports = []
    for sc in scenario_matrix(planes=planes,
                              substrates_per_plane=substrates,
                              duration_s=duration_s):
        r = FleetSimulator(sc, seed=seed).run()
        assert r["violations_total"] == 0, \
            (sc.name, r["violations"])
        assert r["real_sleep_calls"] == 0, sc.name
        reports.append(r)
    return reports


def run(svc=None, *, trials: int = N_TRIALS, planes: int = PLANES,
        substrates: int = SUBSTRATES_PER_PLANE,
        duration_s: float = DURATION_S,
        save_as: str = "bench_scenarios") -> list:
    from repro.core.simulator import FleetSimulator, scenario_matrix

    trial_rows = []
    for trial in range(trials):
        seed = BASE_SEED + trial
        reports = _run_matrix(planes, substrates, duration_s, seed)
        trial_rows.append({
            "seed": seed,
            "scenarios": [{
                "scenario": r["scenario"],
                "tasks": r["tasks"],
                "trace_events": r["trace_events"],
                "breaker_transitions": r["breaker_transitions"],
                "outcomes": r["outcomes"],
                "wall_s": r["wall_s"],
                "virtual_speedup": round(duration_s / max(r["wall_s"], 1e-9),
                                         1),
                "trace_hash": r["trace_hash"],
            } for r in reports],
            "total_tasks": sum(r["tasks"] for r in reports),
            "total_wall_s": round(sum(r["wall_s"] for r in reports), 3),
        })

    # determinism: re-running the first scenario with the first trial's
    # seed must reproduce its event-trace hash bit-for-bit
    first = scenario_matrix(planes=planes, substrates_per_plane=substrates,
                            duration_s=duration_s)[0]
    rerun = FleetSimulator(first, seed=BASE_SEED).run()
    want = trial_rows[0]["scenarios"][0]["trace_hash"]
    deterministic = rerun["trace_hash"] == want
    assert deterministic, (rerun["trace_hash"], want)

    speedups = [s["virtual_speedup"] for t in trial_rows
                for s in t["scenarios"]]
    out = {
        "planes": planes,
        "substrates": planes * substrates,
        "virtual_duration_s_per_scenario": duration_s,
        "scenario_matrix_size": len(trial_rows[0]["scenarios"]),
        "trials": trial_rows,
        "all_zero_violations": True,       # asserted per scenario above
        "zero_real_sleeps": True,          # asserted per scenario above
        "same_seed_identical_hash": deterministic,
        "virtual_speedup_median": statistics.median(speedups),
        "virtual_speedup_min": min(speedups),
        "tasks_per_trial_median": statistics.median(
            t["total_tasks"] for t in trial_rows),
    }
    save(save_as, out)

    t0 = trial_rows[0]
    return [
        csv_row("scenarios/matrix", 0.0,
                f"{out['scenario_matrix_size']} scenarios x {planes} planes "
                f"x {planes * substrates} substrates, "
                f"{duration_s:.0f}s simulated each; "
                f"{t0['total_tasks']} tasks/trial; 0 violations"),
        csv_row("scenarios/speedup", 0.0,
                f"virtual time {out['virtual_speedup_min']:.0f}x-"
                f"{max(speedups):.0f}x faster than wall "
                f"(median {out['virtual_speedup_median']:.0f}x); "
                f"0 real sleeps"),
        csv_row("scenarios/determinism", 0.0,
                f"same seed reproduces identical trace hash: "
                f"{deterministic} "
                f"({want[:16]}...)"),
    ]


def smoke() -> list:
    """CI-sized matrix: >=100 planes, full invariant audits, well under a
    minute."""
    return run(trials=1, planes=120, substrates=10, duration_s=300.0,
               save_as="bench_scenarios_smoke")


if __name__ == "__main__":
    import argparse
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized matrix (>=100 planes, <60s)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in (smoke() if args.smoke else run()):
        print(row)
