"""RQ3: externalized HTTP path — 15 invocations, RTT vs backend latency
(paper: backend 3.95 ms, RTT 8.96 ms → boundary cost ≈ 5 ms)."""
from __future__ import annotations

import statistics

from repro.core import TaskRequest
from benchmarks.common import csv_row, make_testbed, save

RUNS = 15


def run(fast_service) -> list:
    orch, _ = make_testbed(fast_service)
    backend, rtt = [], []
    for _ in range(RUNS):
        res, _ = orch.submit(TaskRequest(
            function="inference", input_modality="vector",
            output_modality="vector", backend_preference="fast-external",
            payload=[0.25, 0.25, 0.25, 0.25]))
        assert res.status == "completed"
        backend.append(res.timing_ms["backend_ms"])
        rtt.append(res.timing_ms["backend_ms"]
                   + res.telemetry["transport_ms"])
    out = {"runs": RUNS,
           "backend_ms_mean": statistics.fmean(backend),
           "rtt_ms_mean": statistics.fmean(rtt),
           "boundary_cost_ms": statistics.fmean(rtt) - statistics.fmean(backend)}
    save("bench_http", out)
    return [csv_row("http/backend", out["backend_ms_mean"] * 1e3, ""),
            csv_row("http/rtt", out["rtt_ms_mean"] * 1e3,
                    f"boundary={out['boundary_cost_ms']:.3f}ms")]
