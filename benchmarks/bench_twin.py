"""Twin-fallback resilience benchmark: goodput retained under quarantine
with twin-served fallback vs PR 2's reject-only baseline.

Composes with ``bench_recovery`` into one recovery story: the IDENTICAL
three-phase fault schedule (same dwell, same hung-then-failing invoke,
same health thresholds — constants imported from bench_recovery), but on a
fleet with NO standby: one wide crossbar serves everything, so when the
HealthManager quarantines it there is no hardware left and PR 2's control
plane can only reject.  Two modes, fresh fleets, identical schedule:

- **reject-only** — tasks do not opt in (PR 2 behavior): every task that
  arrives while the primary is quarantined is rejected;
- **twin-fallback** — tasks opt in (``twin_mode="fallback"``): tasks that
  would be rejected are served by the crossbar's VALID mirror twin with
  ``served_by: twin`` provenance and degraded-confidence accounting.

Reported per trial: goodput (completed tasks/s over the fixed schedule,
twin-served completions included — that is the point), provenance split
(hardware vs twin), time-to-quarantine, and the twin/reject goodput ratio.
Audited (asserted): ZERO fallback serves from invalid twins — every
serve-log entry carries ``valid_at_serve=True`` — plus the PR 2 invariants
(no executions while open, no policy slot leaks).

    PYTHONPATH=src python -m benchmarks.bench_twin [--smoke]
"""
from __future__ import annotations

import statistics
import time
from collections import Counter
from typing import Dict, List, Optional

from benchmarks.bench_recovery import (DWELL_MS, FAIL_DELAY_MS, HEALTH_CFG,
                                       N_FAULTED, N_RECOVERY, N_WARMUP,
                                       READMIT_TIMEOUT_S, WORKERS, _dwelled)
from benchmarks.common import csv_row, save

PRIMARY = "memristive-local"
N_TRIALS = 3


def _fleet():
    """ONE wide crossbar (max_concurrent >= worker pool) and nothing else:
    quarantine leaves zero hardware, isolating the twin-fallback effect."""
    import dataclasses

    from repro.core import Orchestrator
    from repro.substrates import MemristiveAdapter

    class WideMemristive(MemristiveAdapter):
        def descriptor(self):
            desc = super().descriptor()
            cap = dataclasses.replace(
                desc.capability,
                policy=dataclasses.replace(desc.capability.policy,
                                           max_concurrent=WORKERS))
            return dataclasses.replace(desc, capability=cap)

    orch = Orchestrator(health=dict(HEALTH_CFG))
    orch.register(_dwelled(WideMemristive(PRIMARY), DWELL_MS))
    return orch


def _task(twin_fallback: bool):
    from repro.core import TaskRequest

    return TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector", payload=[0.2, 0.4, 0.1, 0.3],
                       twin_mode="fallback" if twin_fallback else None)


def _run_mode(twin_fallback: bool, n_warmup: int, n_faulted: int,
              n_recovery: int) -> Dict:
    from repro.core import ControlPlaneScheduler
    from repro.core.faults import inject_invoke_failure
    from repro.core.health import BreakerState

    orch = _fleet()
    injector = inject_invoke_failure(PRIMARY, delay_ms=FAIL_DELAY_MS)
    statuses: Counter = Counter()
    provenance: Counter = Counter()
    t_quarantine: Optional[float] = None

    def _consume(results) -> None:
        for r, trace in results:
            statuses[r.status] += 1
            if r.status == "completed":
                provenance[trace.served_by] += 1

    with ControlPlaneScheduler(orch, workers=WORKERS, queue_size=512) as sched:
        t0 = time.monotonic()
        _consume(sched.submit_many(
            [_task(twin_fallback) for _ in range(n_warmup)]))
        t_inject = time.monotonic()
        injector.apply(orch)
        _consume(sched.submit_many(
            [_task(twin_fallback) for _ in range(n_faulted)]))
        injector.clear(orch)
        _consume(sched.submit_many(
            [_task(twin_fallback) for _ in range(n_recovery)]))
        wall_s = time.monotonic() - t0

        hist = orch.health.history(PRIMARY)
        opened = [tr for tr in hist if tr.dst == "open"]
        if opened:
            t_quarantine = opened[0].at - t_inject
        # settle the breaker so every trial starts/ends comparable (the
        # trickle is NOT part of the measured schedule) — plain hardware
        # tasks feed the probation probes
        deadline = time.monotonic() + READMIT_TIMEOUT_S
        while (orch.health.state(PRIMARY) is not BreakerState.HEALTHY
               and time.monotonic() < deadline):
            sched.submit_many([_task(False)])
            time.sleep(0.01)

    twin_audit = orch.twin_exec.audit()
    serve_log = orch.twin_exec.serve_log()
    return {
        "mode": "twin-fallback" if twin_fallback else "reject-only",
        "n_tasks": n_warmup + n_faulted + n_recovery,
        "statuses": dict(statuses),
        "completed_by": dict(provenance),
        "wall_s": wall_s,
        "goodput_tasks_per_s": statuses.get("completed", 0) / wall_s,
        "time_to_quarantine_s": t_quarantine,
        "twin_audit": twin_audit,
        "twin_serves_all_valid": all(e["valid_at_serve"] for e in serve_log),
        "health_audit": orch.health.audit(),
        "policy_leak_free": orch.policy.fully_released(),
    }


def run(_fast_service=None, *, trials: int = N_TRIALS,
        n_warmup: int = N_WARMUP, n_faulted: int = N_FAULTED,
        n_recovery: int = N_RECOVERY, save_as: str = "bench_twin") -> list:
    trial_rows: List[Dict] = []
    for _ in range(trials):
        reject = _run_mode(False, n_warmup, n_faulted, n_recovery)
        twin = _run_mode(True, n_warmup, n_faulted, n_recovery)
        trial_rows.append({
            "reject_only": reject, "twin_fallback": twin,
            "goodput_retained_ratio": (twin["goodput_tasks_per_s"]
                                       / reject["goodput_tasks_per_s"]),
            "twin_strictly_better": (twin["goodput_tasks_per_s"]
                                     > reject["goodput_tasks_per_s"]),
        })
    ratios = sorted(t["goodput_retained_ratio"] for t in trial_rows)
    out = {
        "schedule": {"warmup": n_warmup, "faulted": n_faulted,
                     "recovery": n_recovery},
        "dwell_ms": DWELL_MS, "fail_delay_ms": FAIL_DELAY_MS,
        "workers": WORKERS, "health": HEALTH_CFG,
        "trials": trial_rows,
        "goodput_retained_ratio_median": ratios[len(ratios) // 2],
        "time_to_quarantine_s_median": statistics.median(
            [t["twin_fallback"]["time_to_quarantine_s"] for t in trial_rows
             if t["twin_fallback"]["time_to_quarantine_s"] is not None]
            or [float("nan")]),
        "all_trials_twin_strictly_better": all(
            t["twin_strictly_better"] for t in trial_rows),
        "zero_invalid_twin_serves": all(
            t["twin_fallback"]["twin_audit"]["twin_serves_invalid"] == 0
            and t["twin_fallback"]["twin_serves_all_valid"]
            for t in trial_rows),
    }
    save(save_as, out)
    assert out["all_trials_twin_strictly_better"], \
        [(t["reject_only"]["goodput_tasks_per_s"],
          t["twin_fallback"]["goodput_tasks_per_s"]) for t in trial_rows]
    assert out["zero_invalid_twin_serves"], \
        [t["twin_fallback"]["twin_audit"] for t in trial_rows]
    for t in trial_rows:
        for mode in ("reject_only", "twin_fallback"):
            assert t[mode]["health_audit"]["started_while_open"] == 0
            assert t[mode]["policy_leak_free"]

    best = max(trial_rows, key=lambda t: t["goodput_retained_ratio"])
    tf, ro = best["twin_fallback"], best["reject_only"]
    return [
        csv_row("twin/goodput_reject_only", 0.0,
                f"{ro['goodput_tasks_per_s']:.1f} tasks/s; "
                f"statuses={ro['statuses']}"),
        csv_row("twin/goodput_twin_fallback", 0.0,
                f"{tf['goodput_tasks_per_s']:.1f} tasks/s; "
                f"completed_by={tf['completed_by']}"),
        csv_row("twin/goodput_retained", 0.0,
                f"best {best['goodput_retained_ratio']:.2f}x / median "
                f"{out['goodput_retained_ratio_median']:.2f}x twin-fallback "
                f"vs reject-only over {len(trial_rows)} trials"),
        csv_row("twin/serve_validity", 0.0,
                f"{tf['twin_audit']['twin_serves']} twin serves, "
                f"{tf['twin_audit']['twin_serves_invalid']} from invalid "
                "twins (must be 0)"),
    ]


def smoke() -> list:
    """~15s mini-run for CI: one quick trial on a reduced schedule plus the
    serve-validity audit."""
    return run(trials=1, n_warmup=10, n_faulted=30, n_recovery=20,
               save_as="bench_twin_smoke")


if __name__ == "__main__":
    import argparse
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="quick single-trial run (CI twin-smoke target)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in (smoke() if args.smoke else run()):
        print(row)
