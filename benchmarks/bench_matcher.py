"""RQ2 selector comparison on the curated 7-task suite (paper: full matcher
7/7 vs random 4/7, modality-only 3/7, latency-only 3/7)."""
from __future__ import annotations

import time

from repro.core.matcher import (LatencyOnlySelector, Matcher,
                                ModalityOnlySelector,
                                RandomAdmissibleSelector)
from benchmarks.common import csv_row, save
from tests.test_matcher import run_suite


def run(fast_service) -> list:
    rows = []
    out = {}
    for cls in (Matcher, RandomAdmissibleSelector, ModalityOnlySelector,
                LatencyOnlySelector):
        t0 = time.perf_counter()
        correct, details = run_suite(cls, fast_service)
        us = (time.perf_counter() - t0) * 1e6 / 7
        out[cls.name] = {"correct": correct, "total": 7,
                         "details": [{"expected": e, "got": g, "ok": ok}
                                     for e, g, ok in details]}
        rows.append(csv_row(f"matcher/{cls.name}", us, f"{correct}/7"))
    save("bench_matcher", out)
    return rows
