"""LM serving bench: continuous-batching goodput + admission under load.

The serving tentpole makes two measurable claims; each gets a section and
an assert, 3 committed trials in ``results/bench_serving.json``.

- **goodput** — one mixed-length arrival trace (small prompt-length set so
  prefill compiles stay bounded; heavy-tailed ``max_new_tokens`` so a few
  long decodes pin any fixed group) served two ways on the same params:

  * *fixed* — the run-to-completion baseline: requests grouped in arrival
    order into batches of ``BATCH``, each group holding its slots until
    the group's longest request finishes (head-of-line blocking + idle
    slots after short rows retire);
  * *continuous* — the same requests through ``submit()`` + the decode
    loop: finished rows leave the batch each step, freed slots re-primed
    from fresh prefills.

  Goodput = generated tokens / wall second.  Acceptance: continuous >=
  2x fixed on the full run (the ratio is exactly the fixed path's slot
  idleness, paid back).

- **concurrency** — one ``LmServingAdapter`` behind a real
  ``ControlPlaneGateway``; ``SESSIONS`` (>= 128 full-run) client threads
  share one SDK client and ride ``invoke_coalesced`` (submit coalescing +
  long-poll mux).  One request in ``DOOMED_EVERY`` carries a deadline
  budget the roofline admission model cannot meet — those must come back
  as structured ``DEADLINE`` refusals, never tie up batch slots, and
  never trip the breaker for everyone else.  Asserts: every doomed
  request refused as ``DEADLINE``, every admitted request completed,
  p99 engine TTFT within ``TTFT_P99_BOUND_MS``, and **zero mid-decode
  deadline expiries for admitted requests** (the admission model's whole
  point: refuse at the door, never renege mid-decode).

- **paged** — the paged-KV cache (PR 10) against the slot-granular layout
  it replaces, same params, four sub-claims:

  * *parity/goodput* — the mixed-length trace served continuously on both
    layouts must be token-for-token identical, at >= 1.0x goodput with the
    paged pool holding a fraction of the slot-granular KV HBM.  The paged
    producer uses admission backpressure: on ``QUEUE_SATURATED`` it steps
    the engine and retries, so the pool only covers live + queued
    reservations (batch x worst-case pages per request), not the whole
    trace — which is the layout's entire point;
  * *capacity* — under the SAME KV HBM budget, 64-token requests admit 2x
    deeper: the slot-granular engine burns a full 128-token row per
    request, the paged engine only the 4 pages each actually needs;
  * *prefix TTFT* — a prefix-heavy trace (64-token shared prefix, short
    suffixes) with prefix sharing on vs off: suffix-only prefill must cut
    p50 TTFT by >= 30%;
  * *saturation* — overfilling the pool refuses with structured
    ``QUEUE_SATURATED`` + ``retry_after_s``, and a drained engine audits
    zero leaked pages.

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke]

``--smoke`` (make serving-smoke, CI) shrinks the trace and session count,
keeps every correctness assert (refusal taxonomy, zero expiries, admitted
completion, paged token parity, saturation taxonomy, leak audit) and drops
only the perf bounds — tiny traces make the ratios noisy, and CI machines
should not fail on throughput weather.
"""
from __future__ import annotations

import statistics
import threading
import time
from collections import deque
from typing import Dict, List

from benchmarks.common import csv_row, save

N_TRIALS = 3

# -- goodput trace (full run) -------------------------------------------------
BATCH = 8
MAX_SEQ = 128
N_REQS = 64
PROMPT_LENS = (6, 7, 8, 9)        # small set: prefill compiles stay bounded
LIGHT_MAX_NEW = (2, 3)
HEAVY_MAX_NEW = 64                # the tail that pins a fixed batch
HEAVY_EVERY = 8                   # 1 in 8 requests is heavy
GOODPUT_RATIO_MIN = 2.0

# -- paged kv -----------------------------------------------------------------
PAGE_SIZE = 16
LONG_PROMPT = 24                  # long-request shape: 24 prompt + 40 decode
LONG_MAX_NEW = 40                 # = 64 tokens = 4 pages of 16
PREFIX_LEN = 64                   # shared prefix of the prefix-heavy trace
N_PREFIX_REQS = 16
PAGED_GOODPUT_MIN = 1.0
CAPACITY_RATIO_MIN = 2.0
TTFT_REDUCTION_MIN = 0.30

# -- gateway concurrency ------------------------------------------------------
SESSIONS = 128
WORKERS = 64
DOOMED_EVERY = 8
DOOMED_BUDGET_MS = 20.0           # cannot cover HEAVY_MAX_NEW decode steps
ADMITTED_BUDGET_MS = 60_000.0     # generous but real: expiry bookkeeping on
TTFT_P99_BOUND_MS = 2_000.0

ARCH = "internlm2-20b"


def _pct(xs: List[float], p: float) -> float:
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(p * (len(xs) - 1)))]


def _trace(rng, cfg, n_reqs: int, heavy_max_new: int):
    """Mixed-length arrival trace: (prompt, max_new) pairs, heavy-tailed."""
    out = []
    for i in range(n_reqs):
        plen = int(rng.choice(PROMPT_LENS))
        prompt = rng.integers(1, cfg.vocab_size, size=plen).astype("int32")
        max_new = heavy_max_new if i % HEAVY_EVERY == HEAVY_EVERY - 1 \
            else int(rng.choice(LIGHT_MAX_NEW))
        out.append((prompt, max_new))
    return out


def _fixed_run(eng, trace) -> Dict:
    """Run-to-completion baseline: arrival-order groups of ``batch_size``."""
    from repro.serving import Request

    reqs = [Request(f"f{i}", p, max_new_tokens=mn)
            for i, (p, mn) in enumerate(trace)]
    b = eng.batch_size
    t0 = time.perf_counter()
    for i in range(0, len(reqs), b):
        eng.generate(reqs[i:i + b])
    wall_s = time.perf_counter() - t0
    tokens = sum(len(r.generated) for r in reqs)
    assert all(r.done and len(r.generated) == r.max_new_tokens for r in reqs)
    return {"tokens": tokens, "wall_s": wall_s,
            "tokens_per_s": tokens / wall_s}


def _continuous_run(eng, trace) -> Dict:
    """Same trace through the continuous path: submit all, drain."""
    from repro.serving import Request

    reqs = [Request(f"c{i}", p, max_new_tokens=mn)
            for i, (p, mn) in enumerate(trace)]
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    eng.drain()
    wall_s = time.perf_counter() - t0
    tokens = sum(len(r.generated) for r in reqs)
    assert all(r.done and len(r.generated) == r.max_new_tokens for r in reqs)
    ttfts = [r.ttft_ms for r in reqs if r.ttft_ms is not None]
    return {"tokens": tokens, "wall_s": wall_s,
            "tokens_per_s": tokens / wall_s,
            "ttft_p50_ms": _pct(ttfts, 0.50), "ttft_p99_ms": _pct(ttfts, 0.99)}


def _goodput_section(smoke: bool) -> Dict:
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.models import model_specs
    from repro.models.common import init_params
    from repro.serving import ServingEngine

    cfg = reduced(get_config(ARCH))
    params = init_params(model_specs(cfg), seed=1)
    batch = 4 if smoke else BATCH
    n_reqs = 12 if smoke else N_REQS
    heavy = 24 if smoke else HEAVY_MAX_NEW
    fixed_eng = ServingEngine(cfg, params=params, batch_size=batch,
                              max_seq=MAX_SEQ)
    cont_eng = ServingEngine(cfg, params=params, batch_size=batch,
                             max_seq=MAX_SEQ)
    # identical trace every trial (shapes compile once in the warmup;
    # trials then measure steady-state serving, not XLA compile weather)
    trace = _trace(np.random.default_rng(7), cfg, n_reqs, heavy)
    _fixed_run(fixed_eng, trace)
    _continuous_run(cont_eng, trace)
    trials = []
    for _ in range(1 if smoke else N_TRIALS):
        fixed = _fixed_run(fixed_eng, trace)
        cont = _continuous_run(cont_eng, trace)
        trials.append({"fixed": fixed, "continuous": cont,
                       "goodput_ratio": cont["tokens_per_s"]
                       / fixed["tokens_per_s"]})
    ratios = [t["goodput_ratio"] for t in trials]
    section = {
        "batch_size": batch, "n_requests": n_reqs,
        "prompt_lens": list(PROMPT_LENS), "heavy_max_new": heavy,
        "heavy_every": HEAVY_EVERY, "light_max_new": list(LIGHT_MAX_NEW),
        "trials": trials,
        "goodput_ratio_median": statistics.median(ratios),
        "goodput_ratio_min": min(ratios),
    }
    if not smoke:
        assert min(ratios) >= GOODPUT_RATIO_MIN, \
            f"continuous batching goodput ratio {min(ratios):.2f} " \
            f"< {GOODPUT_RATIO_MIN}x over fixed-batch baseline"
    return section


def _paged_section(smoke: bool) -> Dict:
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.core.errors import AdmissionRefused, ErrorCode
    from repro.models import model_specs
    from repro.models.common import init_params
    from repro.serving import Request, ServingEngine

    cfg = reduced(get_config(ARCH))
    params = init_params(model_specs(cfg), seed=1)

    def continuous(eng, trace, tag):
        """Submit with admission backpressure: a ``QUEUE_SATURATED``
        refusal steps the engine (freeing pages) and retries — the
        work-conserving client loop the structured refusal is for.  An
        engine without a pool never refuses, so the dense baseline runs
        the identical loop."""
        reqs = [Request(f"{tag}{i}", p, max_new_tokens=mn)
                for i, (p, mn) in enumerate(trace)]
        pending = deque(reqs)
        t0 = time.perf_counter()
        while pending:
            try:
                eng.submit(pending[0])
                pending.popleft()
            except AdmissionRefused:
                eng.step()
        eng.drain()
        wall_s = time.perf_counter() - t0
        assert all(r.done and len(r.generated) == r.max_new_tokens
                   for r in reqs)
        tokens = sum(len(r.generated) for r in reqs)
        ttfts = [r.ttft_ms for r in reqs]
        stats = {"tokens": tokens, "wall_s": round(wall_s, 4),
                 "tokens_per_s": round(tokens / wall_s, 2),
                 "ttft_p50_ms": round(_pct(ttfts, 0.50), 3)}
        return stats, [r.generated for r in reqs]

    # 1) parity + goodput: the mixed-length trace on both layouts ------------
    batch = 4 if smoke else BATCH
    n_reqs = 12 if smoke else N_REQS
    heavy = 24 if smoke else HEAVY_MAX_NEW
    trace = _trace(np.random.default_rng(7), cfg, n_reqs, heavy)
    # pool sized for live work only: batch x worst-case pages per request.
    # Backpressure in ``continuous`` holds the rest of the trace at the
    # door, so the paged engine serves the same trace in a fraction of the
    # slot-granular KV HBM (batch x MAX_SEQ tokens).
    pool = batch * max(-(-(len(p) + mn) // PAGE_SIZE) for p, mn in trace)
    hbm_fraction = pool * PAGE_SIZE / (batch * MAX_SEQ)
    dense_eng = ServingEngine(cfg, params=params, batch_size=batch,
                              max_seq=MAX_SEQ)
    paged_eng = ServingEngine(cfg, params=params, batch_size=batch,
                              max_seq=MAX_SEQ, paged=True,
                              page_size=PAGE_SIZE, pool_pages=pool)
    continuous(dense_eng, trace, "w")          # compile warmup, both paths
    continuous(paged_eng, trace, "w")
    trials = []
    for _ in range(1 if smoke else N_TRIALS):
        dense, dense_out = continuous(dense_eng, trace, "d")
        paged, paged_out = continuous(paged_eng, trace, "p")
        assert paged_out == dense_out, \
            "paged decode diverged from slot-granular (token parity)"
        trials.append({"dense": dense, "paged": paged,
                       "goodput_ratio": round(paged["tokens_per_s"]
                                              / dense["tokens_per_s"], 4)})
    ratios = [t["goodput_ratio"] for t in trials]
    if not smoke:
        assert max(ratios) >= PAGED_GOODPUT_MIN, \
            f"paged goodput ratio {max(ratios):.3f} < {PAGED_GOODPUT_MIN}x " \
            f"of the slot-granular path"

    # 2) capacity: same KV HBM, 2x the concurrent long requests -------------
    cap_batch = 4
    hbm_tokens = cap_batch * MAX_SEQ               # slot-granular KV budget
    n_long = 2 * cap_batch
    rng = np.random.default_rng(21)
    long_trace = [(rng.integers(1, cfg.vocab_size,
                                size=LONG_PROMPT).astype("int32"),
                   LONG_MAX_NEW) for _ in range(n_long)]
    dense_cap = ServingEngine(cfg, params=params, batch_size=cap_batch,
                              max_seq=MAX_SEQ)
    paged_cap = ServingEngine(cfg, params=params, batch_size=n_long,
                              max_seq=MAX_SEQ, paged=True,
                              page_size=PAGE_SIZE,
                              pool_pages=hbm_tokens // PAGE_SIZE,
                              prefix_sharing=False)
    for eng in (dense_cap, paged_cap):
        for r in [Request(f"c{i}", p, max_new_tokens=mn)
                  for i, (p, mn) in enumerate(long_trace)]:
            eng.submit(r)                          # all reservations fit
        eng.step()                                 # admit as deep as layout allows
    dense_live, paged_live = dense_cap.live_slots(), paged_cap.live_slots()
    dense_cap.drain()
    paged_cap.drain()
    capacity_ratio = paged_live / dense_live
    assert capacity_ratio >= CAPACITY_RATIO_MIN, \
        f"paged concurrent capacity {paged_live} vs {dense_live} " \
        f"({capacity_ratio:.2f}x < {CAPACITY_RATIO_MIN}x at equal HBM)"
    assert paged_cap.audit_pages()["used"] == 0
    capacity = {"kv_hbm_tokens": hbm_tokens,
                "request_tokens": LONG_PROMPT + LONG_MAX_NEW,
                "dense_concurrent": dense_live,
                "paged_concurrent": paged_live,
                "capacity_ratio": capacity_ratio}

    # 3) prefix-heavy trace: suffix-only prefill cuts TTFT -------------------
    rng = np.random.default_rng(22)
    prefix = rng.integers(1, cfg.vocab_size, size=PREFIX_LEN).astype("int32")
    n_pref = 8 if smoke else N_PREFIX_REQS
    pref_trace = [(np.concatenate([prefix, rng.integers(
        1, cfg.vocab_size, size=4 + i % 4).astype("int32")]), 8)
        for i in range(n_pref)]
    # full-queue pool: every request admits up front, so TTFT differences
    # are prefill cost, not admission backpressure.  Wide batch keeps the
    # queue shallow — deep queues bury the prefill saving under decode
    # wait that both engines pay identically.
    pref_pool = sum(-(-(len(p) + mn) // PAGE_SIZE) for p, mn in pref_trace)
    kw = dict(batch_size=BATCH, max_seq=MAX_SEQ, paged=True,
              page_size=PAGE_SIZE, pool_pages=pref_pool)
    cold_eng = ServingEngine(cfg, params=params, prefix_sharing=False, **kw)
    warm_eng = ServingEngine(cfg, params=params, prefix_sharing=True, **kw)
    continuous(cold_eng, pref_trace, "w")      # compile warmup; also warms
    continuous(warm_eng, pref_trace, "w")      # the prefix cache
    cold, cold_out = continuous(cold_eng, pref_trace, "n")
    warm, warm_out = continuous(warm_eng, pref_trace, "s")
    assert warm_out == cold_out, \
        "prefix-shared decode diverged from private-pages decode"
    ttft_reduction = 1.0 - warm["ttft_p50_ms"] / cold["ttft_p50_ms"]
    if not smoke:
        assert ttft_reduction >= TTFT_REDUCTION_MIN, \
            f"prefix cache cut p50 TTFT by {ttft_reduction:.0%} " \
            f"< {TTFT_REDUCTION_MIN:.0%}"
    prefix_stats = warm_eng.pool_stats()
    prefix_section = {"prefix_len": PREFIX_LEN, "n_requests": n_pref,
                      "no_sharing": cold, "sharing": warm,
                      "ttft_p50_reduction": round(ttft_reduction, 4),
                      "prefix_hit_rate": prefix_stats["prefix_hit_rate"]}

    # 4) saturation: structured refusal + zero-leak audit --------------------
    sat_eng = ServingEngine(cfg, params=params, batch_size=2,
                            max_seq=MAX_SEQ, paged=True,
                            page_size=PAGE_SIZE, pool_pages=8,
                            prefix_sharing=False)
    rng = np.random.default_rng(23)
    held = [sat_eng.submit(Request(f"s{i}", rng.integers(
        1, cfg.vocab_size, size=LONG_PROMPT).astype("int32"),
        max_new_tokens=LONG_MAX_NEW)) for i in range(2)]
    try:
        sat_eng.submit(Request("over", rng.integers(
            1, cfg.vocab_size, size=LONG_PROMPT).astype("int32"),
            max_new_tokens=LONG_MAX_NEW))
        raise AssertionError("pool overfill was not refused")
    except AdmissionRefused as e:
        assert e.code is ErrorCode.QUEUE_SATURATED
        assert e.detail["retry_after_s"] > 0
        refusal = {"code": e.code.value,
                   "retry_after_s": e.detail["retry_after_s"],
                   "needed_pages": e.detail["needed_pages"]}
    sat_eng.drain()
    assert all(r.done for r in held)
    audit = sat_eng.audit_pages()
    assert audit["used"] == 0 and audit["reserved"] == 0, \
        f"page leak after drain: {audit}"

    return {"page_size": PAGE_SIZE, "batch_size": batch,
            "n_requests": n_reqs, "pool_pages": pool,
            "kv_hbm_fraction": round(hbm_fraction, 4), "trials": trials,
            "goodput_ratio_best": max(ratios), "capacity": capacity,
            "prefix": prefix_section,
            "saturation": {"refusal": refusal, "audit": audit}}


def _flood_trial(client, sessions: int) -> Dict:
    """``sessions`` concurrent threads, each one coalesced invoke; a
    deterministic 1-in-``DOOMED_EVERY`` carries an unmeetable budget."""
    from repro.core import TaskRequest
    from repro.core.errors import ErrorCode
    from repro.gateway.client import GatewayError

    lock = threading.Lock()
    completed: List[Dict] = []
    refused: List[str] = []
    unexpected: List[str] = []

    def one(i: int) -> None:
        doomed = i % DOOMED_EVERY == DOOMED_EVERY - 1
        plen = PROMPT_LENS[i % len(PROMPT_LENS)]
        task = TaskRequest(
            function="generate", input_modality="tokens",
            output_modality="tokens",
            payload={"prompt": [1 + (i + j) % 50 for j in range(plen)],
                     "max_new_tokens": HEAVY_MAX_NEW if doomed
                     else 2 + i % 5},
            latency_budget_ms=DOOMED_BUDGET_MS if doomed
            else ADMITTED_BUDGET_MS)
        t0 = time.perf_counter()
        try:
            res, _ = client.invoke_coalesced(task)
            wall_ms = (time.perf_counter() - t0) * 1e3
            with lock:
                completed.append({"doomed": doomed, "wall_ms": wall_ms,
                                  "telemetry": dict(res.telemetry)})
        except GatewayError as e:
            with lock:
                (refused if e.code is ErrorCode.DEADLINE
                 else unexpected).append(f"{'doomed' if doomed else 'ok'}-"
                                         f"{i}: {e.code.value}")

    threads = [threading.Thread(target=one, args=(i,), daemon=True)
               for i in range(sessions)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=180.0)
    wall_s = time.perf_counter() - t0
    assert not unexpected, f"non-DEADLINE failures: {unexpected[:5]}"
    n_doomed = sessions // DOOMED_EVERY
    assert not any(c["doomed"] for c in completed) \
        and len(refused) == n_doomed, \
        f"expected {n_doomed} DEADLINE refusals, got {len(refused)} " \
        f"({sum(c['doomed'] for c in completed)} doomed served)"
    assert len(completed) == sessions - n_doomed, \
        f"admitted completions {len(completed)} != {sessions - n_doomed}"
    ttfts = [c["telemetry"]["ttft_ms"] for c in completed]
    walls = [c["wall_ms"] for c in completed]
    expired = sum(bool(c["telemetry"].get("deadline_expired"))
                  for c in completed)
    assert expired == 0, \
        f"{expired} admitted requests expired mid-decode (admission model " \
        f"must refuse at the door instead)"
    return {
        "sessions": sessions, "wall_s": round(wall_s, 3),
        "completed": len(completed), "deadline_refused": len(refused),
        "mid_decode_expiries": expired,
        "ttft_p50_ms": round(_pct(ttfts, 0.50), 3),
        "ttft_p99_ms": round(_pct(ttfts, 0.99), 3),
        "e2e_p50_ms": round(_pct(walls, 0.50), 3),
        "e2e_p99_ms": round(_pct(walls, 0.99), 3),
    }


def _concurrency_section(smoke: bool) -> Dict:
    from repro.core import Orchestrator, TaskRequest
    from repro.gateway import ControlPlaneClient, ControlPlaneGateway
    from repro.substrates import LmServingAdapter

    sessions = 16 if smoke else SESSIONS
    orch = Orchestrator(plane="serving-bench")
    adapter = LmServingAdapter(batch_size=BATCH, max_seq=MAX_SEQ,
                               max_concurrent=max(sessions, 256))
    orch.register(adapter)
    gw = ControlPlaneGateway(orch, plane="serving-bench",
                             workers=WORKERS).start()
    client = ControlPlaneClient(gw.url, timeout_s=120.0)
    try:
        # warm in-process first: builds the engine, compiles prefill for
        # every prompt length the flood uses, seeds the cost model
        for plen in PROMPT_LENS:
            res, _ = orch.execute(TaskRequest(
                function="generate", input_modality="tokens",
                output_modality="tokens",
                payload={"prompt": list(range(1, plen + 1)),
                         "max_new_tokens": 4}))
            assert res.status == "completed"
        trials = [_flood_trial(client, sessions)
                  for _ in range(1 if smoke else N_TRIALS)]
        p99s = [t["ttft_p99_ms"] for t in trials]
        if not smoke:
            assert max(p99s) <= TTFT_P99_BOUND_MS, \
                f"p99 TTFT {max(p99s):.1f}ms over {TTFT_P99_BOUND_MS}ms " \
                f"bound at {sessions} sessions"
        m = adapter.engine.metrics
        assert m["deadline_expired"] == 0
        return {"sessions": sessions, "workers": WORKERS,
                "doomed_every": DOOMED_EVERY,
                "doomed_budget_ms": DOOMED_BUDGET_MS,
                "trials": trials, "ttft_p99_worst_ms": max(p99s),
                "engine_requests": m["requests"],
                "engine_deadline_expired": m["deadline_expired"],
                "cost_model": adapter.cost.snapshot()}
    finally:
        client.close()
        gw.stop()
        adapter.close()


def run(fast_service, smoke: bool = False) -> List[str]:
    del fast_service                    # serving brings its own substrate
    goodput = _goodput_section(smoke)
    paged = _paged_section(smoke)
    conc = _concurrency_section(smoke)
    payload = {"arch": ARCH, "max_seq": MAX_SEQ, "smoke": smoke,
               "goodput": goodput, "paged": paged, "concurrency": conc}
    save("bench_serving_smoke" if smoke else "bench_serving", payload)
    best = max(t["continuous"]["tokens_per_s"] for t in goodput["trials"])
    fixed = max(t["fixed"]["tokens_per_s"] for t in goodput["trials"])
    t0 = conc["trials"][0]
    return [
        csv_row("serving_fixed_tokens_per_s", fixed,
                f"batch={goodput['batch_size']} run-to-completion"),
        csv_row("serving_continuous_tokens_per_s", best,
                f"goodput_ratio_median="
                f"{goodput['goodput_ratio_median']:.2f}x"),
        csv_row("serving_paged_tokens_per_s",
                max(t["paged"]["tokens_per_s"] for t in paged["trials"]),
                f"vs_dense={paged['goodput_ratio_best']:.2f}x "
                f"at_hbm={paged['kv_hbm_fraction']:.0%} "
                f"capacity={paged['capacity']['capacity_ratio']:.1f}x "
                f"prefix_ttft_cut="
                f"{paged['prefix']['ttft_p50_reduction']:.0%}"),
        csv_row("serving_ttft_p99_ms", conc["ttft_p99_worst_ms"],
                f"sessions={conc['sessions']} "
                f"refused={t0['deadline_refused']} "
                f"expired={conc['engine_deadline_expired']}"),
    ]


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for row in run(None, smoke=args.smoke):
        print(row, flush=True)


if __name__ == "__main__":
    main()
