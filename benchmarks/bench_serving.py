"""LM serving bench: continuous-batching goodput + admission under load.

The serving tentpole makes two measurable claims; each gets a section and
an assert, 3 committed trials in ``results/bench_serving.json``.

- **goodput** — one mixed-length arrival trace (small prompt-length set so
  prefill compiles stay bounded; heavy-tailed ``max_new_tokens`` so a few
  long decodes pin any fixed group) served two ways on the same params:

  * *fixed* — the run-to-completion baseline: requests grouped in arrival
    order into batches of ``BATCH``, each group holding its slots until
    the group's longest request finishes (head-of-line blocking + idle
    slots after short rows retire);
  * *continuous* — the same requests through ``submit()`` + the decode
    loop: finished rows leave the batch each step, freed slots re-primed
    from fresh prefills.

  Goodput = generated tokens / wall second.  Acceptance: continuous >=
  2x fixed on the full run (the ratio is exactly the fixed path's slot
  idleness, paid back).

- **concurrency** — one ``LmServingAdapter`` behind a real
  ``ControlPlaneGateway``; ``SESSIONS`` (>= 128 full-run) client threads
  share one SDK client and ride ``invoke_coalesced`` (submit coalescing +
  long-poll mux).  One request in ``DOOMED_EVERY`` carries a deadline
  budget the roofline admission model cannot meet — those must come back
  as structured ``DEADLINE`` refusals, never tie up batch slots, and
  never trip the breaker for everyone else.  Asserts: every doomed
  request refused as ``DEADLINE``, every admitted request completed,
  p99 engine TTFT within ``TTFT_P99_BOUND_MS``, and **zero mid-decode
  deadline expiries for admitted requests** (the admission model's whole
  point: refuse at the door, never renege mid-decode).

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke]

``--smoke`` (make serving-smoke, CI) shrinks the trace and session count,
keeps every correctness assert (refusal taxonomy, zero expiries, admitted
completion) and drops only the 2x perf bound — tiny traces make the
ratio noisy, and CI machines should not fail on throughput weather.
"""
from __future__ import annotations

import statistics
import threading
import time
from typing import Dict, List

from benchmarks.common import csv_row, save

N_TRIALS = 3

# -- goodput trace (full run) -------------------------------------------------
BATCH = 8
MAX_SEQ = 128
N_REQS = 64
PROMPT_LENS = (6, 7, 8, 9)        # small set: prefill compiles stay bounded
LIGHT_MAX_NEW = (2, 3)
HEAVY_MAX_NEW = 64                # the tail that pins a fixed batch
HEAVY_EVERY = 8                   # 1 in 8 requests is heavy
GOODPUT_RATIO_MIN = 2.0

# -- gateway concurrency ------------------------------------------------------
SESSIONS = 128
WORKERS = 64
DOOMED_EVERY = 8
DOOMED_BUDGET_MS = 20.0           # cannot cover HEAVY_MAX_NEW decode steps
ADMITTED_BUDGET_MS = 60_000.0     # generous but real: expiry bookkeeping on
TTFT_P99_BOUND_MS = 2_000.0

ARCH = "internlm2-20b"


def _pct(xs: List[float], p: float) -> float:
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(p * (len(xs) - 1)))]


def _trace(rng, cfg, n_reqs: int, heavy_max_new: int):
    """Mixed-length arrival trace: (prompt, max_new) pairs, heavy-tailed."""
    out = []
    for i in range(n_reqs):
        plen = int(rng.choice(PROMPT_LENS))
        prompt = rng.integers(1, cfg.vocab_size, size=plen).astype("int32")
        max_new = heavy_max_new if i % HEAVY_EVERY == HEAVY_EVERY - 1 \
            else int(rng.choice(LIGHT_MAX_NEW))
        out.append((prompt, max_new))
    return out


def _fixed_run(eng, trace) -> Dict:
    """Run-to-completion baseline: arrival-order groups of ``batch_size``."""
    from repro.serving import Request

    reqs = [Request(f"f{i}", p, max_new_tokens=mn)
            for i, (p, mn) in enumerate(trace)]
    b = eng.batch_size
    t0 = time.perf_counter()
    for i in range(0, len(reqs), b):
        eng.generate(reqs[i:i + b])
    wall_s = time.perf_counter() - t0
    tokens = sum(len(r.generated) for r in reqs)
    assert all(r.done and len(r.generated) == r.max_new_tokens for r in reqs)
    return {"tokens": tokens, "wall_s": wall_s,
            "tokens_per_s": tokens / wall_s}


def _continuous_run(eng, trace) -> Dict:
    """Same trace through the continuous path: submit all, drain."""
    from repro.serving import Request

    reqs = [Request(f"c{i}", p, max_new_tokens=mn)
            for i, (p, mn) in enumerate(trace)]
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    eng.drain()
    wall_s = time.perf_counter() - t0
    tokens = sum(len(r.generated) for r in reqs)
    assert all(r.done and len(r.generated) == r.max_new_tokens for r in reqs)
    ttfts = [r.ttft_ms for r in reqs if r.ttft_ms is not None]
    return {"tokens": tokens, "wall_s": wall_s,
            "tokens_per_s": tokens / wall_s,
            "ttft_p50_ms": _pct(ttfts, 0.50), "ttft_p99_ms": _pct(ttfts, 0.99)}


def _goodput_section(smoke: bool) -> Dict:
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.models import model_specs
    from repro.models.common import init_params
    from repro.serving import ServingEngine

    cfg = reduced(get_config(ARCH))
    params = init_params(model_specs(cfg), seed=1)
    batch = 4 if smoke else BATCH
    n_reqs = 12 if smoke else N_REQS
    heavy = 24 if smoke else HEAVY_MAX_NEW
    fixed_eng = ServingEngine(cfg, params=params, batch_size=batch,
                              max_seq=MAX_SEQ)
    cont_eng = ServingEngine(cfg, params=params, batch_size=batch,
                             max_seq=MAX_SEQ)
    # identical trace every trial (shapes compile once in the warmup;
    # trials then measure steady-state serving, not XLA compile weather)
    trace = _trace(np.random.default_rng(7), cfg, n_reqs, heavy)
    _fixed_run(fixed_eng, trace)
    _continuous_run(cont_eng, trace)
    trials = []
    for _ in range(1 if smoke else N_TRIALS):
        fixed = _fixed_run(fixed_eng, trace)
        cont = _continuous_run(cont_eng, trace)
        trials.append({"fixed": fixed, "continuous": cont,
                       "goodput_ratio": cont["tokens_per_s"]
                       / fixed["tokens_per_s"]})
    ratios = [t["goodput_ratio"] for t in trials]
    section = {
        "batch_size": batch, "n_requests": n_reqs,
        "prompt_lens": list(PROMPT_LENS), "heavy_max_new": heavy,
        "heavy_every": HEAVY_EVERY, "light_max_new": list(LIGHT_MAX_NEW),
        "trials": trials,
        "goodput_ratio_median": statistics.median(ratios),
        "goodput_ratio_min": min(ratios),
    }
    if not smoke:
        assert min(ratios) >= GOODPUT_RATIO_MIN, \
            f"continuous batching goodput ratio {min(ratios):.2f} " \
            f"< {GOODPUT_RATIO_MIN}x over fixed-batch baseline"
    return section


def _flood_trial(client, sessions: int) -> Dict:
    """``sessions`` concurrent threads, each one coalesced invoke; a
    deterministic 1-in-``DOOMED_EVERY`` carries an unmeetable budget."""
    from repro.core import TaskRequest
    from repro.core.errors import ErrorCode
    from repro.gateway.client import GatewayError

    lock = threading.Lock()
    completed: List[Dict] = []
    refused: List[str] = []
    unexpected: List[str] = []

    def one(i: int) -> None:
        doomed = i % DOOMED_EVERY == DOOMED_EVERY - 1
        plen = PROMPT_LENS[i % len(PROMPT_LENS)]
        task = TaskRequest(
            function="generate", input_modality="tokens",
            output_modality="tokens",
            payload={"prompt": [1 + (i + j) % 50 for j in range(plen)],
                     "max_new_tokens": HEAVY_MAX_NEW if doomed
                     else 2 + i % 5},
            latency_budget_ms=DOOMED_BUDGET_MS if doomed
            else ADMITTED_BUDGET_MS)
        t0 = time.perf_counter()
        try:
            res, _ = client.invoke_coalesced(task)
            wall_ms = (time.perf_counter() - t0) * 1e3
            with lock:
                completed.append({"doomed": doomed, "wall_ms": wall_ms,
                                  "telemetry": dict(res.telemetry)})
        except GatewayError as e:
            with lock:
                (refused if e.code is ErrorCode.DEADLINE
                 else unexpected).append(f"{'doomed' if doomed else 'ok'}-"
                                         f"{i}: {e.code.value}")

    threads = [threading.Thread(target=one, args=(i,), daemon=True)
               for i in range(sessions)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=180.0)
    wall_s = time.perf_counter() - t0
    assert not unexpected, f"non-DEADLINE failures: {unexpected[:5]}"
    n_doomed = sessions // DOOMED_EVERY
    assert not any(c["doomed"] for c in completed) \
        and len(refused) == n_doomed, \
        f"expected {n_doomed} DEADLINE refusals, got {len(refused)} " \
        f"({sum(c['doomed'] for c in completed)} doomed served)"
    assert len(completed) == sessions - n_doomed, \
        f"admitted completions {len(completed)} != {sessions - n_doomed}"
    ttfts = [c["telemetry"]["ttft_ms"] for c in completed]
    walls = [c["wall_ms"] for c in completed]
    expired = sum(bool(c["telemetry"].get("deadline_expired"))
                  for c in completed)
    assert expired == 0, \
        f"{expired} admitted requests expired mid-decode (admission model " \
        f"must refuse at the door instead)"
    return {
        "sessions": sessions, "wall_s": round(wall_s, 3),
        "completed": len(completed), "deadline_refused": len(refused),
        "mid_decode_expiries": expired,
        "ttft_p50_ms": round(_pct(ttfts, 0.50), 3),
        "ttft_p99_ms": round(_pct(ttfts, 0.99), 3),
        "e2e_p50_ms": round(_pct(walls, 0.50), 3),
        "e2e_p99_ms": round(_pct(walls, 0.99), 3),
    }


def _concurrency_section(smoke: bool) -> Dict:
    from repro.core import Orchestrator, TaskRequest
    from repro.gateway import ControlPlaneClient, ControlPlaneGateway
    from repro.substrates import LmServingAdapter

    sessions = 16 if smoke else SESSIONS
    orch = Orchestrator(plane="serving-bench")
    adapter = LmServingAdapter(batch_size=BATCH, max_seq=MAX_SEQ,
                               max_concurrent=max(sessions, 256))
    orch.register(adapter)
    gw = ControlPlaneGateway(orch, plane="serving-bench",
                             workers=WORKERS).start()
    client = ControlPlaneClient(gw.url, timeout_s=120.0)
    try:
        # warm in-process first: builds the engine, compiles prefill for
        # every prompt length the flood uses, seeds the cost model
        for plen in PROMPT_LENS:
            res, _ = orch.execute(TaskRequest(
                function="generate", input_modality="tokens",
                output_modality="tokens",
                payload={"prompt": list(range(1, plen + 1)),
                         "max_new_tokens": 4}))
            assert res.status == "completed"
        trials = [_flood_trial(client, sessions)
                  for _ in range(1 if smoke else N_TRIALS)]
        p99s = [t["ttft_p99_ms"] for t in trials]
        if not smoke:
            assert max(p99s) <= TTFT_P99_BOUND_MS, \
                f"p99 TTFT {max(p99s):.1f}ms over {TTFT_P99_BOUND_MS}ms " \
                f"bound at {sessions} sessions"
        m = adapter.engine.metrics
        assert m["deadline_expired"] == 0
        return {"sessions": sessions, "workers": WORKERS,
                "doomed_every": DOOMED_EVERY,
                "doomed_budget_ms": DOOMED_BUDGET_MS,
                "trials": trials, "ttft_p99_worst_ms": max(p99s),
                "engine_requests": m["requests"],
                "engine_deadline_expired": m["deadline_expired"],
                "cost_model": adapter.cost.snapshot()}
    finally:
        client.close()
        gw.stop()
        adapter.close()


def run(fast_service, smoke: bool = False) -> List[str]:
    del fast_service                    # serving brings its own substrate
    goodput = _goodput_section(smoke)
    conc = _concurrency_section(smoke)
    payload = {"arch": ARCH, "max_seq": MAX_SEQ, "smoke": smoke,
               "goodput": goodput, "concurrency": conc}
    save("bench_serving_smoke" if smoke else "bench_serving", payload)
    best = max(t["continuous"]["tokens_per_s"] for t in goodput["trials"])
    fixed = max(t["fixed"]["tokens_per_s"] for t in goodput["trials"])
    t0 = conc["trials"][0]
    return [
        csv_row("serving_fixed_tokens_per_s", fixed,
                f"batch={goodput['batch_size']} run-to-completion"),
        csv_row("serving_continuous_tokens_per_s", best,
                f"goodput_ratio_median="
                f"{goodput['goodput_ratio_median']:.2f}x"),
        csv_row("serving_ttft_p99_ms", conc["ttft_p99_worst_ms"],
                f"sessions={conc['sessions']} "
                f"refused={t0['deadline_refused']} "
                f"expired={conc['engine_deadline_expired']}"),
    ]


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for row in run(None, smoke=args.smoke):
        print(row, flush=True)


if __name__ == "__main__":
    main()
