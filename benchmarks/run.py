"""Benchmark harness: one bench per paper table/figure + framework extras.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows and writes machine-readable
JSON under benchmarks/results/.

Paper artifact map:
    bench_portability — Table III + RQ1 shared-key ratios
    bench_matcher     — RQ2 selector comparison (7-task suite)
    bench_faults      — Table IV fault campaign
    bench_overhead    — RQ3 local control-path cost (25 runs × 3 backends)
    bench_http        — RQ3 externalized HTTP path (15 invocations)
    bench_cortical    — §VIII-A/C Cortical Labs end-to-end (3 directed runs)
    bench_roofline    — EXPERIMENTS.md §Roofline table (dry-run cache)
    bench_fleet       — beyond-paper orchestrated TPU-fleet training
    bench_throughput  — beyond-paper sustained throughput: serial submit
                        loop vs pooled ControlPlaneScheduler
    bench_recovery    — beyond-paper resilience: goodput under faults with
                        vs without the HealthManager (circuit breakers)
    bench_twin        — beyond-paper executable twins: goodput retained
                        under quarantine with twin-served fallback vs the
                        reject-only baseline (same fault schedule as
                        bench_recovery; zero-invalid-serves audited)
    bench_gateway     — beyond-paper wire API: control-path overhead of the
                        gateway + client SDK vs the in-process plane
                        (reproduces the paper's "small control-path
                        overhead" across a real protocol boundary)
    bench_hierarchy   — beyond-paper multi-hop federation: per-hop added
                        control latency on a device→edge→fog→cloud chain
                        (vs the single-hop wire margin) and streaming
                        telemetry fan-in vs the N-cursor polling baseline
                        (request count + zero-loss by sequence numbers)
    bench_serving     — beyond-paper LM serving substrate: continuous
                        batching vs fixed-batch goodput on a mixed-length
                        arrival trace (>= 2x), p99 TTFT + structured
                        DEADLINE admission refusals under >= 128
                        concurrent gateway sessions (zero mid-decode
                        expiries for admitted requests)
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import (bench_cortical, bench_faults, bench_fleet,
                        bench_gateway, bench_hierarchy, bench_http,
                        bench_matcher, bench_overhead, bench_portability,
                        bench_recovery, bench_roofline, bench_scenarios,
                        bench_serving, bench_throughput, bench_twin)

BENCHES = {
    "portability": bench_portability.run,
    "matcher": bench_matcher.run,
    "faults": bench_faults.run,
    "overhead": bench_overhead.run,
    "http": bench_http.run,
    "cortical": bench_cortical.run,
    "roofline": bench_roofline.run,
    "fleet": bench_fleet.run,
    "throughput": bench_throughput.run,
    "recovery": bench_recovery.run,
    "twin": bench_twin.run,
    "gateway": bench_gateway.run,
    "hierarchy": bench_hierarchy.run,
    "serving": bench_serving.run,
    "scenarios": bench_scenarios.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(BENCHES))
    args = ap.parse_args()

    from repro.substrates.http_fast import FastService
    svc = FastService().start()
    print("name,us_per_call,derived")
    try:
        for name, fn in BENCHES.items():
            if args.only and name != args.only:
                continue
            for row in fn(svc):
                print(row, flush=True)
    finally:
        svc.stop()


if __name__ == '__main__':
    main()
