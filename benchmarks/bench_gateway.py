"""Wire-path control overhead: in-process plane vs gateway + client SDK.

The paper's RQ3 result is "small local control-path overhead"; the
protocol-first redesign must keep that true ACROSS the wire.  Same task,
same substrate, two paths:

- **local** — ``Orchestrator.execute`` called in-process (the PR 1-3 path);
- **wire** — the identical orchestrator behind a ``ControlPlaneGateway``,
  driven through ``ControlPlaneClient.invoke`` over loopback HTTP.

Per call we record the CONTROL PATH cost — wall time minus the backend's
own execution time (``backend_ms``) — so substrate variance cancels and
the difference between the two medians is exactly what the wire adds:
protocol encode/decode, one HTTP round-trip, scheduler hand-off.  The v1.2
wire path (selector loop, direct worker-thread sends, binary codec) is
held to a sub-millisecond budget: median wire excess p50 <= 1 ms on the
default codec, 3 committed trials in ``results/bench_gateway.json``.

Three extra sections exercise what the rework bought:

- **per-codec trials** — the overhead trial runs under BOTH wire codecs
  (``json`` and the v1.2 binary envelope) so a codec regression is visible
  in the committed numbers, not just in unit tests;
- **tensor frames** — a 1024-float activation payload encoded both ways:
  frame sizes (binary packs raw doubles, JSON prints digits) plus the
  wired invoke latency carrying that payload;
- **concurrency churn sweep** — sustained connect→request→close sessions
  at K concurrent slots against (a) the selector-loop gateway and (b) an
  in-bench ``ThreadingHTTPServer`` baseline mirroring the pre-v1.2 server
  (thread per connection, default listen backlog).  Capacity is the
  largest K with <=0.5 % session errors (a 2 s per-session deadline counts
  as an error — stuck-in-SYN sessions don't get to hide) and p99 within
  bound; the acceptance assert wants the async gateway at >=10x the
  threaded baseline's capacity.

    PYTHONPATH=src python -m benchmarks.bench_gateway [--smoke]

``--smoke`` (make bench-gateway-smoke, CI) runs a discover → invoke →
telemetry round-trip plus one quick overhead trial per codec and asserts
the same p50 budget, in well under 30 s; the churn sweep is full-run only.
"""
from __future__ import annotations

import errno
import random
import selectors
import socket
import statistics
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Tuple

from benchmarks.common import csv_row, save

RUNS = 80
N_TRIALS = 3
WIRE_EXCESS_BOUND_MS = 1.0        # p50 budget, default codec
CODECS = ("json", "binary")

TENSOR_LEN = 1024

CHURN_LADDER = (4, 8, 16, 32, 64, 128, 256, 512)
CHURN_DURATION_S = 1.0
CHURN_DEADLINE_S = 2.0            # per-session; lapse counts as an error
CHURN_ERR_RATE_MAX = 0.005
CHURN_P99_BOUND_MS = 500.0
CAPACITY_RATIO_MIN = 10.0

TASK_KW = dict(function="inference", input_modality="vector",
               output_modality="vector", payload=[0.2, 0.2, 0.2, 0.2],
               required_telemetry=("execution_ms",),
               backend_preference="memristive-local")


def _pct(xs: List[float], p: float) -> float:
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(p * (len(xs) - 1)))]


def _control_ms(invoke, runs: int) -> List[float]:
    """Per-call control-path cost: wall − backend_ms."""
    out = []
    for _ in range(runs):
        t0 = time.perf_counter()
        res, _ = invoke()
        wall_ms = (time.perf_counter() - t0) * 1e3
        assert res.status == "completed", res.telemetry
        out.append(wall_ms - res.timing_ms.get("backend_ms", 0.0))
    return out


def _trial(fast_service, runs: int, codec: str) -> Dict:
    from repro.core import Orchestrator, TaskRequest
    from repro.gateway import ControlPlaneClient, ControlPlaneGateway
    from repro.substrates import standard_testbed

    orch = Orchestrator()
    standard_testbed(orch, http_service=fast_service)
    gw = ControlPlaneGateway(orch, plane="bench").start()
    client = ControlPlaneClient(gw.url, codec=codec)
    try:
        # warm both paths (scheduler threads, HTTP keep-alive, jit-ish)
        for _ in range(5):
            orch.submit(TaskRequest(**TASK_KW))
            client.invoke(TaskRequest(**TASK_KW))
        local = _control_ms(lambda: orch.submit(TaskRequest(**TASK_KW)), runs)
        wired = _control_ms(lambda: client.invoke(TaskRequest(**TASK_KW)),
                            runs)
    finally:
        client.close()
        gw.stop()
    return {
        "codec": codec, "runs": runs,
        "local_p50_ms": _pct(local, 0.50), "local_p99_ms": _pct(local, 0.99),
        "wire_p50_ms": _pct(wired, 0.50), "wire_p99_ms": _pct(wired, 0.99),
        "wire_excess_p50_ms": _pct(wired, 0.50) - _pct(local, 0.50),
        "local_mean_ms": statistics.fmean(local),
        "wire_mean_ms": statistics.fmean(wired),
    }


# ---------------------------------------------------------------------------
# tensor frames: what the binary envelope buys on activation payloads


def _tensor_section(fast_service, runs: int) -> Dict:
    from repro.core import Orchestrator, TaskRequest
    from repro.gateway import ControlPlaneClient, ControlPlaneGateway
    from repro.gateway import protocol as wire
    from repro.substrates import standard_testbed

    rng = random.Random(0xBEEF)
    payload = [rng.uniform(-1.0, 1.0) for _ in range(TENSOR_LEN)]
    kw = dict(TASK_KW, payload=payload)
    env = wire.request_envelope("invoke", {
        "task": wire.task_to_wire(TaskRequest(**kw)), "deadline_s": 30.0})
    json_bytes = len(wire.dumps(env))
    bin_bytes = len(wire.dumps_binary(env))

    orch = Orchestrator()
    standard_testbed(orch, http_service=fast_service)
    gw = ControlPlaneGateway(orch, plane="tensor").start()
    out: Dict = {
        "tensor_len": TENSOR_LEN,
        "json_frame_bytes": json_bytes,
        "binary_frame_bytes": bin_bytes,
        "frame_size_ratio": json_bytes / bin_bytes,
    }
    try:
        for codec in CODECS:
            client = ControlPlaneClient(gw.url, codec=codec)
            try:
                for _ in range(5):
                    client.invoke(TaskRequest(**kw))
                wired = _control_ms(
                    lambda: client.invoke(TaskRequest(**kw)), runs)
            finally:
                client.close()
            out[f"{codec}_wire_p50_ms"] = _pct(wired, 0.50)
    finally:
        gw.stop()
    return out


# ---------------------------------------------------------------------------
# concurrency churn sweep: selector gateway vs thread-per-conn baseline


_CHURN_REQ = (b"GET /v1/health HTTP/1.1\r\nHost: bench\r\n"
              b"Connection: close\r\n\r\n")


def _churn_level(host: str, port: int, k: int,
                 duration_s: float = CHURN_DURATION_S,
                 deadline_s: float = CHURN_DEADLINE_S) -> Dict:
    """K concurrent connect→GET /v1/health→close sessions, sustained for
    ``duration_s``.  A session past ``deadline_s`` is reaped as an error —
    this is what stops a backlogged server's stuck-in-SYN sessions from
    flattering its latency percentiles by never finishing."""
    sel = selectors.DefaultSelector()
    lat: List[float] = []
    errors = 0
    sessions: Dict[int, Dict] = {}

    def spawn() -> None:
        s = socket.socket()
        s.setblocking(False)
        try:
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass
        rc = s.connect_ex((host, port))
        if rc not in (0, errno.EINPROGRESS, errno.EWOULDBLOCK):
            s.close()
            return
        sess = {"sock": s, "fd": s.fileno(), "start": time.perf_counter(),
                "wrote": False, "buf": b""}
        sessions[sess["fd"]] = sess
        sel.register(s, selectors.EVENT_WRITE, sess)

    def reap(sess: Dict, ok: bool) -> None:
        nonlocal errors
        try:
            sel.unregister(sess["sock"])
        except (KeyError, ValueError, OSError):
            pass
        try:
            sess["sock"].close()
        except OSError:
            pass
        sessions.pop(sess["fd"], None)
        if ok and b" 200 " in sess["buf"]:
            lat.append((time.perf_counter() - sess["start"]) * 1e3)
        else:
            errors += 1

    t_end = time.perf_counter() + duration_s
    for _ in range(k):
        spawn()
    while sessions:
        opening = time.perf_counter() < t_end
        for ev, _mask in sel.select(timeout=0.05):
            sess = ev.data
            s = sess["sock"]
            try:
                if not sess["wrote"]:
                    err = s.getsockopt(socket.SOL_SOCKET, socket.SO_ERROR)
                    if err:
                        raise OSError(err, "connect failed")
                    if s.send(_CHURN_REQ) != len(_CHURN_REQ):
                        raise OSError(errno.EPIPE, "short send")
                    sess["wrote"] = True
                    sel.modify(s, selectors.EVENT_READ, sess)
                else:
                    data = s.recv(65536)
                    if data:
                        if len(sess["buf"]) < 256:
                            sess["buf"] += data
                    else:               # server closed: response complete
                        reap(sess, ok=True)
                        if opening:
                            spawn()
            except OSError:
                reap(sess, ok=False)
                if opening:
                    spawn()
        now = time.perf_counter()
        for sess in list(sessions.values()):
            if now - sess["start"] > deadline_s:
                reap(sess, ok=False)
                if now < t_end:
                    spawn()
    done = len(lat)
    out = {"k": k, "done": done, "errors": errors,
           "err_rate": errors / max(done + errors, 1),
           "rps": done / duration_s,
           "p50_ms": _pct(lat, 0.50) if lat else None,
           "p99_ms": _pct(lat, 0.99) if lat else None}
    return out


class _BaselineHandler(BaseHTTPRequestHandler):
    """Canned health response — the baseline pays only for threading."""
    _body = b'{"ok": true, "plane": "baseline"}'

    def do_GET(self):                                   # noqa: N802
        self.send_response(200)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(self._body)))
        self.end_headers()
        self.wfile.write(self._body)

    def log_message(self, *args):
        pass


class _BaselineServer(ThreadingHTTPServer):
    """Thread-per-connection server shaped like the pre-v1.2 gateway:
    daemon request threads, stock listen backlog (request_queue_size=5)."""
    daemon_threads = True

    def handle_error(self, request, client_address):
        pass              # churned peers hang up mid-write; keep quiet


def _capacity(host: str, port: int) -> Dict:
    levels = []
    capacity = 0
    for k in CHURN_LADDER:
        level = _churn_level(host, port, k)
        levels.append(level)
        ok = (level["err_rate"] <= CHURN_ERR_RATE_MAX
              and level["p99_ms"] is not None
              and level["p99_ms"] <= CHURN_P99_BOUND_MS)
        if not ok:
            break
        capacity = k
    return {"levels": levels, "capacity": capacity}


def _churn_section() -> Dict:
    from repro.core import Orchestrator
    from repro.gateway import ControlPlaneGateway
    from repro.substrates import MemristiveAdapter

    baseline = _BaselineServer(("127.0.0.1", 0), _BaselineHandler)
    threading.Thread(target=baseline.serve_forever, daemon=True,
                     name="bench-baseline-http").start()
    bl_host, bl_port = baseline.server_address

    orch = Orchestrator()
    orch.register(MemristiveAdapter("m0"))
    gw = ControlPlaneGateway(orch, plane="churn").start()
    try:
        threaded = _capacity(bl_host, bl_port)
        asynch = _capacity("127.0.0.1", gw.port)
    finally:
        gw.stop()
        baseline.shutdown()
        baseline.server_close()
    ratio = (asynch["capacity"] / threaded["capacity"]
             if threaded["capacity"] else float("inf"))
    return {
        "duration_s": CHURN_DURATION_S, "deadline_s": CHURN_DEADLINE_S,
        "err_rate_max": CHURN_ERR_RATE_MAX,
        "p99_bound_ms": CHURN_P99_BOUND_MS,
        "threaded": threaded, "async": asynch,
        "capacity_ratio": ratio,
    }


def _smoke_roundtrip(fast_service) -> Dict:
    """discover → invoke → telemetry against the standard mixed testbed,
    over the wire; asserts each leg."""
    from repro.core import Orchestrator, TaskRequest
    from repro.gateway import ControlPlaneClient, ControlPlaneGateway
    from repro.substrates import standard_testbed

    orch = Orchestrator()
    standard_testbed(orch, http_service=fast_service)
    gw = ControlPlaneGateway(orch, plane="smoke").start()
    client = ControlPlaneClient(gw.url)
    try:
        descs = client.discover()
        assert len(descs) == len(orch.discover()) >= 5
        cursor = client.telemetry(cursor=0)["next_cursor"]
        res, trace = client.invoke(TaskRequest(**TASK_KW))
        assert res.status == "completed" and trace.selected
        tail = client.telemetry(cursor=cursor, timeout_s=5.0)
        assert tail["events"], "invoke events must reach the telemetry cursor"
        return {"resources": len(descs), "invoked_on": res.resource_id,
                "telemetry_events": len(tail["events"])}
    finally:
        client.close()
        gw.stop()


def run(fast_service, smoke: bool = False) -> list:
    runs = 20 if smoke else RUNS
    n_trials = 1 if smoke else N_TRIALS
    roundtrip = _smoke_roundtrip(fast_service) if smoke else None

    trials = [_trial(fast_service, runs, codec)
              for _ in range(n_trials) for codec in CODECS]
    by_codec = {codec: statistics.median(
        t["wire_excess_p50_ms"] for t in trials if t["codec"] == codec)
        for codec in CODECS}
    excess = by_codec["json"]           # the default codec carries the bound
    payload = {
        "trials": trials,
        "median_wire_excess_p50_ms": excess,
        "wire_excess_p50_ms_by_codec": by_codec,
        "bound_ms": WIRE_EXCESS_BOUND_MS,
        "within_bound": excess <= WIRE_EXCESS_BOUND_MS,
        "tensor": _tensor_section(fast_service, runs),
    }
    if not smoke:
        payload["churn"] = _churn_section()
    if roundtrip is not None:
        payload["smoke_roundtrip"] = roundtrip
    save("bench_gateway_smoke" if smoke else "bench_gateway", payload)

    assert excess <= WIRE_EXCESS_BOUND_MS, (
        f"wire control path adds {excess:.3f} ms median "
        f"(> {WIRE_EXCESS_BOUND_MS} ms bound)")
    rows = [csv_row(
        "gateway/wire_excess_p50", excess * 1e3,
        f"json={by_codec['json']:.3f}ms binary={by_codec['binary']:.3f}ms "
        f"local_p50={trials[0]['local_p50_ms']:.3f}ms "
        f"wire_p50={trials[0]['wire_p50_ms']:.3f}ms trials={n_trials}")]
    tensor = payload["tensor"]
    rows.append(csv_row(
        "gateway/tensor_frame_bytes", tensor["binary_frame_bytes"],
        f"json={tensor['json_frame_bytes']}B "
        f"ratio={tensor['frame_size_ratio']:.2f}x "
        f"wire_p50 json={tensor['json_wire_p50_ms']:.3f}ms "
        f"binary={tensor['binary_wire_p50_ms']:.3f}ms"))
    if not smoke:
        churn = payload["churn"]
        assert churn["capacity_ratio"] >= CAPACITY_RATIO_MIN, (
            f"async churn capacity {churn['async']['capacity']} is only "
            f"{churn['capacity_ratio']:.1f}x the threaded baseline "
            f"{churn['threaded']['capacity']} (need {CAPACITY_RATIO_MIN}x)")
        rows.append(csv_row(
            "gateway/churn_capacity", churn["async"]["capacity"],
            f"threaded={churn['threaded']['capacity']} "
            f"ratio={churn['capacity_ratio']:.1f}x "
            f"err<={CHURN_ERR_RATE_MAX:.1%} p99<={CHURN_P99_BOUND_MS:.0f}ms"))
    return rows


def main() -> None:
    import argparse

    from repro.substrates import FastService

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI round-trip + 1 overhead trial (<30s)")
    args = ap.parse_args()
    svc = FastService().start()
    try:
        for row in run(svc, smoke=args.smoke):
            print(row, flush=True)
    finally:
        svc.stop()


if __name__ == "__main__":
    main()
