"""Wire-path control overhead: in-process plane vs gateway + client SDK.

The paper's RQ3 result is "small local control-path overhead"; the
protocol-first redesign must keep that true ACROSS the wire.  Same task,
same substrate, two paths:

- **local** — ``Orchestrator.execute`` called in-process (the PR 1-3 path);
- **wire** — the identical orchestrator behind a ``ControlPlaneGateway``,
  driven through ``ControlPlaneClient.invoke`` over loopback HTTP.

Per call we record the CONTROL PATH cost — wall time minus the backend's
own execution time (``backend_ms``) — so substrate variance cancels and the
difference between the two medians is exactly what the wire adds: protocol
encode/decode, one HTTP round-trip, scheduler hand-off.  Reported per
trial: p50/p99 for both paths and the median wire excess; the acceptance
bound asserts median excess <= 5 ms (3 committed trials in
``results/bench_gateway.json``).

    PYTHONPATH=src python -m benchmarks.bench_gateway [--smoke]

``--smoke`` (make gateway-smoke, CI) runs a discover → invoke → telemetry
round-trip against the standard mixed testbed plus one quick overhead
trial, in well under 30 s.
"""
from __future__ import annotations

import statistics
import time
from typing import Dict, List

from benchmarks.common import csv_row, save

RUNS = 80
N_TRIALS = 3
WIRE_EXCESS_BOUND_MS = 5.0

TASK_KW = dict(function="inference", input_modality="vector",
               output_modality="vector", payload=[0.2, 0.2, 0.2, 0.2],
               required_telemetry=("execution_ms",),
               backend_preference="memristive-local")


def _pct(xs: List[float], p: float) -> float:
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(p * (len(xs) - 1)))]


def _control_ms(invoke, runs: int) -> List[float]:
    """Per-call control-path cost: wall − backend_ms."""
    out = []
    for _ in range(runs):
        t0 = time.perf_counter()
        res, _ = invoke()
        wall_ms = (time.perf_counter() - t0) * 1e3
        assert res.status == "completed", res.telemetry
        out.append(wall_ms - res.timing_ms.get("backend_ms", 0.0))
    return out


def _trial(fast_service, runs: int) -> Dict:
    from repro.core import Orchestrator, TaskRequest
    from repro.gateway import ControlPlaneClient, ControlPlaneGateway
    from repro.substrates import standard_testbed

    orch = Orchestrator()
    standard_testbed(orch, http_service=fast_service)
    gw = ControlPlaneGateway(orch, plane="bench").start()
    client = ControlPlaneClient(gw.url)
    try:
        # warm both paths (scheduler threads, HTTP keep-alive, jit-ish)
        for _ in range(5):
            orch.submit(TaskRequest(**TASK_KW))
            client.invoke(TaskRequest(**TASK_KW))
        local = _control_ms(lambda: orch.submit(TaskRequest(**TASK_KW)), runs)
        wired = _control_ms(lambda: client.invoke(TaskRequest(**TASK_KW)),
                            runs)
    finally:
        gw.stop()
    return {
        "runs": runs,
        "local_p50_ms": _pct(local, 0.50), "local_p99_ms": _pct(local, 0.99),
        "wire_p50_ms": _pct(wired, 0.50), "wire_p99_ms": _pct(wired, 0.99),
        "wire_excess_p50_ms": _pct(wired, 0.50) - _pct(local, 0.50),
        "local_mean_ms": statistics.fmean(local),
        "wire_mean_ms": statistics.fmean(wired),
    }


def _smoke_roundtrip(fast_service) -> Dict:
    """discover → invoke → telemetry against the standard mixed testbed,
    over the wire; asserts each leg."""
    from repro.core import Orchestrator, TaskRequest
    from repro.gateway import ControlPlaneClient, ControlPlaneGateway
    from repro.substrates import standard_testbed

    orch = Orchestrator()
    standard_testbed(orch, http_service=fast_service)
    gw = ControlPlaneGateway(orch, plane="smoke").start()
    client = ControlPlaneClient(gw.url)
    try:
        descs = client.discover()
        assert len(descs) == len(orch.discover()) >= 5
        cursor = client.telemetry(cursor=0)["next_cursor"]
        res, trace = client.invoke(TaskRequest(**TASK_KW))
        assert res.status == "completed" and trace.selected
        tail = client.telemetry(cursor=cursor, timeout_s=5.0)
        assert tail["events"], "invoke events must reach the telemetry cursor"
        return {"resources": len(descs), "invoked_on": res.resource_id,
                "telemetry_events": len(tail["events"])}
    finally:
        gw.stop()


def run(fast_service, smoke: bool = False) -> list:
    runs = 20 if smoke else RUNS
    n_trials = 1 if smoke else N_TRIALS
    roundtrip = _smoke_roundtrip(fast_service) if smoke else None

    trials = [_trial(fast_service, runs) for _ in range(n_trials)]
    excess = statistics.median(t["wire_excess_p50_ms"] for t in trials)
    payload = {
        "trials": trials,
        "median_wire_excess_p50_ms": excess,
        "bound_ms": WIRE_EXCESS_BOUND_MS,
        "within_bound": excess <= WIRE_EXCESS_BOUND_MS,
    }
    if roundtrip is not None:
        payload["smoke_roundtrip"] = roundtrip
    save("bench_gateway_smoke" if smoke else "bench_gateway", payload)
    assert excess <= WIRE_EXCESS_BOUND_MS, (
        f"wire control path adds {excess:.3f} ms median "
        f"(> {WIRE_EXCESS_BOUND_MS} ms bound)")
    best = min(t["wire_excess_p50_ms"] for t in trials)
    return [csv_row("gateway/wire_excess_p50", excess * 1e3,
                    f"best={best:.3f}ms local_p50="
                    f"{trials[0]['local_p50_ms']:.3f}ms wire_p50="
                    f"{trials[0]['wire_p50_ms']:.3f}ms trials={n_trials}")]


def main() -> None:
    import argparse

    from repro.substrates import FastService

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI round-trip + 1 overhead trial (<30s)")
    args = ap.parse_args()
    svc = FastService().start()
    try:
        for row in run(svc, smoke=args.smoke):
            print(row, flush=True)
    finally:
        svc.stop()


if __name__ == "__main__":
    main()
