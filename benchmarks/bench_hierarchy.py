"""Hierarchical control plane: per-hop cost + stream-vs-poll fan-in.

Two claims from the multi-hop refactor, measured on a real 4-plane chain
(device → edge → fog → cloud, each boundary a live gateway + wire hop):

1. **Per-hop added control latency** — the control-path cost of a task
   submitted at the CLOUD (3 wire hops to the device substrate) minus the
   cost submitted at the DEVICE directly, divided by the number of hops,
   must not exceed the single-hop wire margin established by
   ``bench_gateway`` (the committed ``results/bench_gateway.json``:
   measured median wire excess, floored by its 5 ms acceptance bound).
   I.e. chaining planes costs hops × single-hop — no superlinear blow-up
   from the topology layer.

2. **Streaming fan-in** — a parent following N child planes with ONE
   ``/v1/stream`` subscription each must deliver the same events as the
   N-cursor long-poll baseline with at least 2× fewer gateway requests and
   ZERO lost events (verified by per-subscription sequence numbers and the
   ring's dropped counters).

``--smoke`` (make hierarchy-smoke, CI) additionally runs the failure
drill: a device → edge → fog chain forwards, the MIDDLE plane is killed,
and the run asserts the fog-side breaker opens via the broken stream and
opted-in traffic twin-serves with zero invalid serves.

    PYTHONPATH=src python -m benchmarks.bench_hierarchy [--smoke]
"""
from __future__ import annotations

import json
import statistics
import threading
import time
from pathlib import Path
from typing import Dict, List

from benchmarks.common import RESULTS, csv_row, save

RUNS = 60
N_TRIALS = 3
CHAIN_HOPS = 3                       # cloud→fog, fog→edge, edge→device
FANIN_CHILDREN = 3
FANIN_EVENTS_PER_CHILD = 20
FALLBACK_MARGIN_MS = 5.0             # bench_gateway's acceptance bound


def _pct(xs: List[float], p: float) -> float:
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(p * (len(xs) - 1)))]


def _single_hop_margin_ms() -> Dict:
    """The committed single-hop wire margin: bench_gateway's measured
    median excess, floored by its 5 ms acceptance bound (one noisy trial
    of THIS bench must not fail against a lucky committed run)."""
    path = RESULTS / "bench_gateway.json"
    measured = None
    if path.exists():
        try:
            data = json.loads(path.read_text())
            measured = float(data["median_wire_excess_p50_ms"])
        except (ValueError, KeyError):
            measured = None
    margin = max(measured or 0.0, FALLBACK_MARGIN_MS)
    return {"measured_single_hop_ms": measured, "margin_ms": margin}


def _task(**kw):
    from repro.core import TaskRequest

    return TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector",
                       payload=[0.2, 0.2, 0.2, 0.2],
                       required_telemetry=("execution_ms",), **kw)


def _control_ms(submit, runs: int) -> List[float]:
    out = []
    for _ in range(runs):
        t0 = time.perf_counter()
        res, _ = submit()
        wall_ms = (time.perf_counter() - t0) * 1e3
        assert res.status == "completed", res.telemetry
        out.append(wall_ms - res.timing_ms.get("backend_ms", 0.0))
    return out


class _Chain:
    """device → edge → fog → cloud, every boundary a live gateway."""

    def __init__(self):
        from repro.core import Orchestrator
        from repro.gateway import ControlPlaneGateway
        from repro.substrates import MemristiveAdapter, federate

        self.planes = {"device": Orchestrator()}
        self.planes["device"].register(MemristiveAdapter("device-xbar"))
        self.gateways = {"device": ControlPlaneGateway(
            self.planes["device"], plane="device").start()}
        self.adapters = {}
        for child, parent in (("device", "edge"), ("edge", "fog"),
                              ("fog", "cloud")):
            self.planes[parent] = Orchestrator()
            self.adapters[parent] = federate(self.planes[parent],
                                             self.gateways[child].url)
            if parent != "cloud":
                self.gateways[parent] = ControlPlaneGateway(
                    self.planes[parent], plane=parent).start()

    def close(self):
        for gw in self.gateways.values():
            gw.stop()
        for a in self.adapters.values():
            a.close()


def _trial_chain(runs: int) -> Dict:
    chain = _Chain()
    try:
        device, cloud = chain.planes["device"], chain.planes["cloud"]
        for _ in range(5):                      # warm every hop + keep-alive
            device.submit(_task())
            res, _ = cloud.submit(_task())
            assert res.telemetry["remote_resource_id"].startswith("plane-")
        local = _control_ms(lambda: device.submit(_task()), runs)
        chained = _control_ms(lambda: cloud.submit(_task()), runs)
    finally:
        chain.close()
    local_p50, chained_p50 = _pct(local, 0.50), _pct(chained, 0.50)
    return {
        "runs": runs,
        "hops": CHAIN_HOPS,
        "device_p50_ms": local_p50, "device_p99_ms": _pct(local, 0.99),
        "cloud_p50_ms": chained_p50, "cloud_p99_ms": _pct(chained, 0.99),
        "added_total_p50_ms": chained_p50 - local_p50,
        "per_hop_added_p50_ms": (chained_p50 - local_p50) / CHAIN_HOPS,
    }


# ---------------------------------------------------------------------------
# fan-in: one stream per child vs N polling cursors


class _Child:
    def __init__(self, idx: int):
        from repro.core import Orchestrator
        from repro.gateway import ControlPlaneClient, ControlPlaneGateway
        from repro.substrates import MemristiveAdapter

        self.rid = f"fanin-xbar-{idx}"
        self.orch = Orchestrator()
        self.orch.register(MemristiveAdapter(self.rid))
        self.gw = ControlPlaneGateway(self.orch,
                                      plane=f"fanin-{idx}").start()
        self.client = ControlPlaneClient(self.gw.url)

    def publish(self, n: int):
        for _ in range(n):
            self.client.invoke(_task())
            time.sleep(0.01)

    def close(self):
        self.gw.stop()


def _collect_polling(children: List[_Child], expect_each: int) -> Dict:
    """N-cursor long-poll baseline: one cursor loop per child, counting
    every gateway request it costs to deliver all result events."""
    requests = 0
    delivered: Dict[str, List[int]] = {c.rid: [] for c in children}
    lock = threading.Lock()

    def follow(child: _Child):
        nonlocal requests
        cursor, got = 0, 0
        while got < expect_each:
            out = child.client.telemetry(cursor=cursor, timeout_s=0.25,
                                         limit=8)
            with lock:
                requests += 1
            assert out["dropped"] == 0, "polling baseline lost events"
            cursor = out["next_cursor"]
            for e in out["events"]:
                if e["kind"] == "result":
                    got += 1
                    delivered[child.rid].append(e["seq"])

    threads = [threading.Thread(target=follow, args=(c,)) for c in children]
    publishers = [threading.Thread(
        target=c.publish, args=(FANIN_EVENTS_PER_CHILD,)) for c in children]
    for t in publishers + threads:
        t.start()
    for t in publishers + threads:
        t.join()
    return {"requests": requests, "delivered": delivered}


def _collect_streaming(children: List[_Child], expect_each: int) -> Dict:
    """One /v1/stream subscription per child: exactly N gateway requests
    however many events flow."""
    delivered: Dict[str, List[int]] = {c.rid: [] for c in children}

    def follow(child: _Child):
        stream = child.client.stream(kinds={"result"}, heartbeat_s=0.5)
        try:
            for e in stream.events(limit=expect_each):
                delivered[child.rid].append(e["seq"])
        finally:
            stream.close()

    threads = [threading.Thread(target=follow, args=(c,)) for c in children]
    publishers = [threading.Thread(
        target=c.publish, args=(FANIN_EVENTS_PER_CHILD,)) for c in children]
    for t in threads + publishers:
        t.start()
    for t in publishers + threads:
        t.join()
    return {"requests": len(children), "delivered": delivered}


def _check_delivery(delivered: Dict[str, List[int]], expect_each: int,
                    label: str) -> None:
    for rid, seqs in delivered.items():
        assert len(seqs) == expect_each, \
            f"{label}: {rid} delivered {len(seqs)}/{expect_each}"
        assert len(set(seqs)) == len(seqs), f"{label}: duplicate seq"
        assert seqs == sorted(seqs), f"{label}: out-of-order delivery"


def _trial_fanin() -> Dict:
    children = [_Child(i) for i in range(FANIN_CHILDREN)]
    try:
        streamed = _collect_streaming(children, FANIN_EVENTS_PER_CHILD)
        _check_delivery(streamed["delivered"], FANIN_EVENTS_PER_CHILD,
                        "stream")
        polled = _collect_polling(children, FANIN_EVENTS_PER_CHILD)
        _check_delivery(polled["delivered"], FANIN_EVENTS_PER_CHILD, "poll")
    finally:
        for c in children:
            c.close()
    return {
        "children": FANIN_CHILDREN,
        "events_per_child": FANIN_EVENTS_PER_CHILD,
        "poll_requests": polled["requests"],
        "stream_requests": streamed["requests"],
        "request_ratio": polled["requests"] / streamed["requests"],
        "lost_events": 0,
    }


# ---------------------------------------------------------------------------
# smoke failure drill: kill the middle plane


def _smoke_kill_middle() -> Dict:
    from repro.core import Orchestrator
    from repro.core.health import BreakerState
    from repro.gateway import ControlPlaneGateway
    from repro.substrates import MemristiveAdapter, federate

    device = Orchestrator()
    device.register(MemristiveAdapter("device-xbar"))
    gw_device = ControlPlaneGateway(device, plane="device").start()
    edge = Orchestrator()
    a_edge = federate(edge, gw_device.url)
    gw_edge = ControlPlaneGateway(edge, plane="edge").start()
    fog = Orchestrator(health=dict(
        cooldown_s=30.0, thresholds={"consecutive_failures_to_open": 2}))
    a_fog = federate(fog, gw_edge.url)
    try:
        for _ in range(6):                      # forward + warm the twin
            res, _ = fog.submit(_task())
            assert res.status == "completed"
        t0 = time.monotonic()
        gw_edge.stop()                          # kill the MIDDLE plane
        while fog.health.state(a_fog.resource_id) is not BreakerState.OPEN:
            assert time.monotonic() - t0 < 10.0, "breaker never tripped"
            time.sleep(0.02)
        trip_s = time.monotonic() - t0
        twin_served = 0
        for _ in range(6):
            res, trace = fog.submit(_task(twin_mode="fallback"))
            assert res.status == "completed"
            twin_served += trace.served_by == "twin"
        audit = fog.twin_exec.audit()
        assert twin_served > 0, "twin must serve while plane quarantined"
        assert audit["twin_serves_invalid"] == 0
        return {"breaker_trip_s": round(trip_s, 3),
                "twin_served": twin_served,
                "twin_serves_invalid": audit["twin_serves_invalid"]}
    finally:
        gw_device.stop()
        a_edge.close()
        a_fog.close()


def run(fast_service=None, smoke: bool = False) -> list:
    runs = 15 if smoke else RUNS
    n_trials = 1 if smoke else N_TRIALS
    margin = _single_hop_margin_ms()

    chain_trials = [_trial_chain(runs) for _ in range(n_trials)]
    fanin_trials = [_trial_fanin() for _ in range(n_trials)]
    per_hop = statistics.median(t["per_hop_added_p50_ms"]
                                for t in chain_trials)
    ratio = min(t["request_ratio"] for t in fanin_trials)
    payload = {
        "chain_trials": chain_trials,
        "fanin_trials": fanin_trials,
        "per_hop_added_p50_ms": per_hop,
        "single_hop_margin": margin,
        "per_hop_within_margin": per_hop <= margin["margin_ms"],
        "min_request_ratio": ratio,
        "request_ratio_ok": ratio >= 2.0,
    }
    if smoke:
        payload["kill_middle_plane"] = _smoke_kill_middle()
    save("bench_hierarchy_smoke" if smoke else "bench_hierarchy", payload)
    assert per_hop <= margin["margin_ms"], (
        f"per-hop added control latency {per_hop:.3f} ms exceeds the "
        f"single-hop wire margin {margin['margin_ms']:.3f} ms")
    assert ratio >= 2.0, (
        f"streaming must at least halve gateway requests "
        f"(worst ratio {ratio:.2f}x)")
    return [
        csv_row("hierarchy/per_hop_added_p50", per_hop * 1e3,
                f"hops={CHAIN_HOPS} margin={margin['margin_ms']:.2f}ms "
                f"cloud_p50={chain_trials[0]['cloud_p50_ms']:.3f}ms "
                f"trials={n_trials}"),
        csv_row("hierarchy/stream_vs_poll_requests", ratio,
                f"poll={fanin_trials[0]['poll_requests']} "
                f"stream={fanin_trials[0]['stream_requests']} "
                f"lost=0 children={FANIN_CHILDREN}"),
    ]


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI trial + kill-middle-plane drill (<60s)")
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(row, flush=True)


if __name__ == "__main__":
    main()
