"""Table IV fault campaign: five scenarios, expected vs observed."""
from __future__ import annotations

import time

from repro.core.faults import build_campaign, run_campaign
from benchmarks.common import csv_row, make_testbed, save


def run(fast_service) -> list:
    def factory():
        orch, _ = make_testbed(fast_service)
        return orch

    t0 = time.perf_counter()
    results = run_campaign(factory, build_campaign())
    us = (time.perf_counter() - t0) * 1e6 / len(results)
    passed = sum(r["pass"] for r in results)
    save("bench_faults", results)
    rows = [csv_row("faults/campaign", us, f"{passed}/{len(results)} expected")]
    for r in results:
        rows.append(csv_row(f"faults/{r['scenario']}", 0.0,
                            f"{r['expected']}->{r['observed']}:"
                            f"{'PASS' if r['pass'] else 'FAIL'}"))
    return rows
