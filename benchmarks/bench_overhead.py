"""RQ3: local control-path overhead — direct adapter vs orchestrated,
25 runs per backend (paper §VIII-C: 0.361 / 0.194 / 0.189 ms, i.e. sub-ms
absolute overhead; multipliers large only because direct invocations are
extremely short)."""
from __future__ import annotations

import statistics
import time

from repro.core import TaskRequest
from benchmarks.common import csv_row, make_testbed, save

RUNS = 25

TASKS = {
    "chemical-ode": dict(function="assay", input_modality="concentration",
                         output_modality="concentration",
                         payload={"concentrations": [0.6, 0.2, 0.1, 0.1]},
                         required_telemetry=("convergence_ms",)),
    "wetware-synthetic": dict(function="screening", input_modality="spikes",
                              output_modality="spikes",
                              payload={"pattern": [1, 0, 1, 1]},
                              required_telemetry=("firing_rate_hz",)),
    "memristive-local": dict(function="inference", input_modality="vector",
                             output_modality="vector",
                             payload=[0.2, 0.2, 0.2, 0.2],
                             required_telemetry=("execution_ms",)),
}


def run(fast_service) -> list:
    orch, adapters = make_testbed(fast_service)
    rows = []
    out = {}
    for rid, task_kw in TASKS.items():
        adapter = adapters[rid]
        # direct path: adapter invoke via a session but no orchestration
        task = TaskRequest(**task_kw, backend_preference=rid)
        desc = orch.registry.get(rid)
        session = orch.invocations.open_session(task, desc)
        adapter.prepare(session)

        direct = []
        for _ in range(RUNS):
            t0 = time.perf_counter()
            adapter.invoke(session)
            direct.append((time.perf_counter() - t0) * 1e3)
        adapter.reset()

        orchestrated, inrun_overhead = [], []
        for _ in range(RUNS):
            t0 = time.perf_counter()
            res, trace = orch.submit(TaskRequest(**task_kw,
                                                 backend_preference=rid))
            assert res.status == "completed", (rid, res.telemetry)
            total = (time.perf_counter() - t0) * 1e3
            orchestrated.append(total)
            # within-run decomposition: control path = wall − backend time
            # (robust to the twins' run-to-run simulation variance)
            inrun_overhead.append(total - res.timing_ms["backend_ms"])
        adapter.reset()

        d_mean = statistics.fmean(direct)
        o_mean = statistics.fmean(orchestrated)
        overhead = statistics.fmean(inrun_overhead)
        factor = o_mean / d_mean if d_mean > 0 else float("inf")
        out[rid] = {"direct_ms": d_mean, "orchestrated_ms": o_mean,
                    "overhead_ms": overhead,
                    "overhead_vs_direct_ms": o_mean - d_mean,
                    "factor": factor, "runs": RUNS}
        rows.append(csv_row(f"overhead/{rid}", overhead * 1e3,
                            f"factor={factor:.2f}x direct={d_mean:.3f}ms"))
    save("bench_overhead", out)
    return rows
