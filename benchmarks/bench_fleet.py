"""Beyond-paper: orchestrated fleet training — placement, straggler
mitigation and checkpoint-restore fallback through the control plane."""
from __future__ import annotations

import os
import tempfile

from repro.substrates.tpu_pod import TpuPodSubstrate
from repro.training.runner import FleetRunner
from benchmarks.common import csv_row, save


def run(_fast_service=None) -> list:
    with tempfile.TemporaryDirectory() as td:
        fr = FleetRunner()
        a = TpuPodSubstrate("rwkv6-7b", recipe="baseline",
                            ckpt_dir=os.path.join(td, "a"), batch=2, seq=32)
        b = TpuPodSubstrate("rwkv6-7b", recipe="tp_only",
                            ckpt_dir=os.path.join(td, "b"), batch=2, seq=32)
        fr.add_slice(a)
        fr.add_slice(b)
        healthy = fr.train(quanta=3, steps_per_quantum=2)
        primary = max(healthy.placements, key=healthy.placements.get)
        fr.slices[primary].inject_straggler(0.4)
        mitigated = fr.train(quanta=2, steps_per_quantum=2)
        fr.slices[primary].inject_fault("prepare_failure")
        recovered = fr.train(quanta=1, steps_per_quantum=1, preferred=primary)
        out = {
            "healthy": {"placements": healthy.placements,
                        "losses": healthy.losses},
            "straggler_mitigated": {"placements": mitigated.placements},
            "failure_recovered": {"placements": recovered.placements,
                                  "fallbacks": recovered.fallbacks},
        }
        save("bench_fleet", out)
        moved = sum(v for k, v in mitigated.placements.items() if k != primary)
        return [
            csv_row("fleet/healthy", healthy.wall_s * 1e6 / 3,
                    f"placements={healthy.placements}"),
            csv_row("fleet/straggler", 0.0,
                    f"moved {moved}/2 quanta off straggler"),
            csv_row("fleet/failure", 0.0,
                    f"recovered on {list(recovered.placements)}"),
        ]
