"""RQ1: descriptor/invocation shared-key ratios + Table III concern matrix."""
from __future__ import annotations

from repro.core import TaskRequest, shared_key_ratio
from benchmarks.common import csv_row, make_testbed, save

# Table III (analytic): which control-plane concerns each approach covers
TABLE_III = {
    "plain-mcp": dict(discovery=1, invocation=1, io="part", time=0,
                      lifecycle=1, telemetry=0, twin=0, selection=0),
    "w3c-wot": dict(discovery=1, invocation=1, io="part", time="part",
                    lifecycle=0, telemetry="part", twin=0, selection=0),
    "nir-mapping": dict(discovery=0, invocation="part", io="part", time=0,
                        lifecycle=0, telemetry=0, twin=0, selection=0),
    "substrate-apis": dict(discovery="part", invocation=1, io=1, time="part",
                           lifecycle="part", telemetry="part", twin="part",
                           selection="part"),
    "phys-mcp": dict(discovery=1, invocation=1, io=1, time=1, lifecycle=1,
                     telemetry=1, twin=1, selection=1),
}

INVOCATIONS = [
    dict(function="assay", input_modality="concentration",
         output_modality="concentration",
         payload={"concentrations": [0.5, 0.2, 0.2, 0.1]}),
    dict(function="screening", input_modality="spikes",
         output_modality="spikes", payload={"pattern": [1, 1, 0, 1]}),
    dict(function="inference", input_modality="vector",
         output_modality="vector", payload=[0.1, 0.2, 0.3, 0.4]),
    dict(function="inference", input_modality="vector",
         output_modality="vector", payload=[0.4, 0.3, 0.2, 0.1],
         backend_preference="fast-external"),
    dict(function="screening", input_modality="spikes",
         output_modality="spikes", payload={"pattern": [1, 0, 1]},
         backend_preference="cortical-labs-backend"),
]


def run(fast_service) -> list:
    orch, adapters = make_testbed(fast_service)
    descs = [orch.registry.get(r).to_dict()
             for r in sorted(orch.registry._resources)]
    desc_ratio = shared_key_ratio(descs)
    cap_ratio = shared_key_ratio([d["capability"] for d in descs])

    results = []
    meta = []
    for kw in INVOCATIONS:
        res, _ = orch.submit(TaskRequest(**kw))
        assert res.status == "completed", (kw, res.telemetry)
        results.append(res.to_dict())
        meta.append({"backend": res.resource_id,
                     "telemetry_keys": sorted(res.telemetry.keys())})
    inv_ratio = shared_key_ratio(results)

    save("bench_portability", {
        "descriptor_shared_key_ratio": desc_ratio,
        "capability_shared_key_ratio": cap_ratio,
        "invocation_shared_key_ratio": inv_ratio,
        "registered_backends": len(descs),
        "executed_backends": sorted({m["backend"] for m in meta}),
        "backend_specific_telemetry": meta,
        "table_iii": TABLE_III,
    })
    return [
        csv_row("portability/descriptor_ratio", 0.0, f"{desc_ratio:.2f}"),
        csv_row("portability/invocation_ratio", 0.0, f"{inv_ratio:.2f}"),
        csv_row("portability/backends", 0.0,
                f"{len(descs)} registered / {len({m['backend'] for m in meta})} executed"),
    ]
