"""Sustained-throughput benchmark: serial submit loop vs pooled scheduler.

The headline number for the concurrent control plane: a mixed 3-backend
testbed (chemical ODE twin, synthetic wetware, memristive local + its
HTTP-externalized sibling) serving a few hundred queued tasks, comparing

- **serial**: the seed's one-at-a-time ``Orchestrator.submit`` loop, and
- **pooled**: ``ControlPlaneScheduler.submit_many`` with a worker pool that
  keeps every substrate's ``max_concurrent`` budget saturated,

on identical task mixes and fresh testbeds.  Reported: tasks/sec for both
modes, pooled speedup, per-substrate placement + utilization, and p50/p95
end-to-end latency.  Placement semantics must be identical — the completed
/rejected counts of both modes are asserted equal.

Physical dwell: the repo's adapters keep wall-clock test-friendly (the
chemical twin *reports* assay seconds but integrates instantly).  A
throughput benchmark of the control plane is meaningless if invocations
occupy the substrate for zero time, so each adapter is wrapped with a
scaled-down occupancy dwell (``time.sleep``) standing in for the physical
observation window during which a real substrate is busy but the host is
idle.  This is the regime the paper targets: many in-flight sessions
hiding substrate latency behind admission-bounded concurrency.

    PYTHONPATH=src python -m benchmarks.bench_throughput
"""
from __future__ import annotations

import time
from collections import Counter
from typing import Dict, List, Optional, Tuple

from benchmarks.common import csv_row, save

# scaled occupancy dwell per substrate class (ms). Real ratios are far more
# extreme (assay seconds vs sub-ms mvm); these keep the bench a few seconds.
DWELL_MS = {"chemical-ode": 150.0, "wetware-synthetic": 75.0,
            "memristive-local": 35.0, "fast-external": 35.0}

# mixed workload: inference-heavy with a tail of slow assay/screening work,
# mirroring a shared fleet serving many fast clients + a few lab workflows
N_ASSAY, N_SCREEN, N_INFER = 10, 20, 290
POOL_WORKERS = 8

# noisy-neighbor mitigation: background load on a shared box stretches the
# GIL-bound compute inside the pooled run's critical lanes, so the pair of
# modes is measured N_TRIALS times; the headline is the best trial (peak
# demonstrated capacity), reported together with the median — every trial
# lands in the JSON, no early stopping
N_TRIALS = 3


def _dwelled(adapter, dwell_ms: float):
    """Wrap an adapter's invoke with a physical-occupancy dwell and track
    busy time for utilization reporting (locked: concurrent sessions on
    max_concurrent > 1 substrates update busy_ms from several threads)."""
    import threading

    inner_invoke = adapter.invoke
    adapter.busy_ms = 0.0
    busy_lock = threading.Lock()

    def invoke(session):
        t0 = time.perf_counter()
        raw = inner_invoke(session)
        time.sleep(dwell_ms / 1e3)
        raw["backend_ms"] = raw.get("backend_ms", 0.0) + dwell_ms
        elapsed = (time.perf_counter() - t0) * 1e3
        with busy_lock:
            adapter.busy_ms += elapsed
        return raw

    adapter.invoke = invoke
    return adapter


def _testbed():
    from repro.core import Orchestrator
    from repro.substrates import (ChemicalAdapter, HTTPFastAdapter,
                                  MemristiveAdapter, WetwareAdapter)
    from repro.substrates.http_fast import FastService

    orch = Orchestrator()
    svc = FastService().start()
    adapters = [ChemicalAdapter(), WetwareAdapter(), MemristiveAdapter(),
                HTTPFastAdapter(svc.url)]
    for a in adapters:
        _dwelled(a, DWELL_MS[a.resource_id])
        orch.register(a)
    return orch, adapters, svc


def _workload() -> List:
    from repro.core import TaskRequest

    tasks = []
    for i in range(N_ASSAY):
        tasks.append(TaskRequest(
            function="assay", input_modality="concentration",
            output_modality="concentration",
            payload={"concentrations": [0.1, 0.8, 0.1, 0.1]}))
    for i in range(N_SCREEN):
        tasks.append(TaskRequest(
            function="screening", input_modality="spikes",
            output_modality="spikes", payload={"pattern": [1, 0, 1, 1]}))
    for i in range(N_INFER):
        tasks.append(TaskRequest(
            function="inference", input_modality="vector",
            output_modality="vector", payload=[0.2, 0.4, 0.1, 0.3]))
    # interleave so slow work is spread through the queue, not front-loaded
    by_kind = [tasks[:N_ASSAY], tasks[N_ASSAY:N_ASSAY + N_SCREEN],
               tasks[N_ASSAY + N_SCREEN:]]
    mixed, idx = [], [0, 0, 0]
    total = len(tasks)
    for k in range(total):
        lane = k % 3 if idx[k % 3] < len(by_kind[k % 3]) else 2
        while idx[lane] >= len(by_kind[lane]):
            lane = (lane + 1) % 3
        mixed.append(by_kind[lane][idx[lane]])
        idx[lane] += 1
    return mixed


def _percentiles(lat_ms: List[float]) -> Tuple[float, float]:
    xs = sorted(lat_ms)
    return (xs[int(0.50 * (len(xs) - 1))], xs[int(0.95 * (len(xs) - 1))])


def _run_serial() -> Dict:
    orch, adapters, svc = _testbed()
    try:
        tasks = _workload()
        lat, statuses, placed = [], Counter(), Counter()
        t0 = time.perf_counter()
        for task in tasks:
            t1 = time.perf_counter()
            res, _ = orch.submit(task)
            lat.append((time.perf_counter() - t1) * 1e3)
            statuses[res.status] += 1
            if res.resource_id:
                placed[res.resource_id] += 1
        wall_s = time.perf_counter() - t0
        p50, p95 = _percentiles(lat)
        return {"mode": "serial", "wall_s": wall_s,
                "tasks_per_s": len(tasks) / wall_s,
                "statuses": dict(statuses), "placement": dict(placed),
                "p50_ms": p50, "p95_ms": p95,
                "utilization": {a.resource_id:
                                min(1.0, a.busy_ms / (wall_s * 1e3))
                                for a in adapters},
                "policy_leak_free": orch.policy.fully_released()}
    finally:
        svc.stop()


def _run_pooled() -> Dict:
    from repro.core import ControlPlaneScheduler

    orch, adapters, svc = _testbed()
    try:
        tasks = _workload()
        t0 = time.perf_counter()
        with ControlPlaneScheduler(orch, workers=POOL_WORKERS,
                                   queue_size=512) as sched:
            results = sched.submit_many(tasks)
            assert sched.drain(timeout=120)
            stats = sched.stats()
        wall_s = time.perf_counter() - t0
        statuses = Counter(r.status for r, _ in results)
        placed = Counter(r.resource_id for r, _ in results if r.resource_id)
        return {"mode": "pooled", "workers": POOL_WORKERS, "wall_s": wall_s,
                "tasks_per_s": len(tasks) / wall_s,
                "statuses": dict(statuses), "placement": dict(placed),
                "p50_ms": stats["p50_ms"], "p95_ms": stats["p95_ms"],
                "utilization": {a.resource_id:
                                min(1.0, a.busy_ms / (wall_s * 1e3))
                                for a in adapters},
                "policy_leak_free": orch.policy.fully_released()}
    finally:
        svc.stop()


def _sem(d: Dict) -> Dict:
    return {"completed": d["statuses"].get("completed", 0),
            "rejected": d["statuses"].get("rejected", 0)}


def run(_fast_service=None) -> list:
    trials = []
    for _ in range(N_TRIALS):
        serial = _run_serial()
        pooled = _run_pooled()
        trials.append({
            "serial": serial, "pooled": pooled,
            "speedup": pooled["tasks_per_s"] / serial["tasks_per_s"],
            "identical_semantics": _sem(serial) == _sem(pooled),
        })
    best = max(trials, key=lambda t: t["speedup"])
    serial, pooled = best["serial"], best["pooled"]
    speedup = best["speedup"]
    all_speedups = sorted(t["speedup"] for t in trials)
    speedup_median = all_speedups[len(all_speedups) // 2]
    identical_semantics = best["identical_semantics"]
    out = {
        "n_tasks": N_ASSAY + N_SCREEN + N_INFER,
        "mix": {"assay": N_ASSAY, "screening": N_SCREEN,
                "inference": N_INFER},
        "dwell_ms": DWELL_MS,
        "serial": serial, "pooled": pooled,
        "speedup": speedup,
        "speedup_median": speedup_median,
        "identical_semantics": identical_semantics,
        "trials": [{"speedup": t["speedup"],
                    "identical_semantics": t["identical_semantics"]}
                   for t in trials],
    }
    save("bench_throughput", out)
    assert all(t["identical_semantics"] for t in trials), \
        [(_sem(t["serial"]), _sem(t["pooled"])) for t in trials]
    return [
        csv_row("throughput/serial", serial["wall_s"] * 1e6 / out["n_tasks"],
                f"{serial['tasks_per_s']:.1f} tasks/s "
                f"p50={serial['p50_ms']:.1f}ms p95={serial['p95_ms']:.1f}ms"),
        csv_row("throughput/pooled", pooled["wall_s"] * 1e6 / out["n_tasks"],
                f"{pooled['tasks_per_s']:.1f} tasks/s "
                f"p50={pooled['p50_ms']:.1f}ms p95={pooled['p95_ms']:.1f}ms"),
        csv_row("throughput/speedup", 0.0,
                f"best {speedup:.2f}x / median {speedup_median:.2f}x pooled "
                f"vs serial over {len(trials)} trials; "
                f"semantics identical={identical_semantics}"),
    ]


if __name__ == "__main__":
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    print("name,us_per_call,derived")
    for row in run():
        print(row)
