"""Shared benchmark utilities: result IO + testbed construction."""
from __future__ import annotations

import json
import statistics
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"


def save(name: str, payload) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    p = RESULTS / f"{name}.json"
    p.write_text(json.dumps(payload, indent=2, default=str))
    return p


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.2f},{derived}"


def timed_runs(fn, n: int):
    xs = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        xs.append((time.perf_counter() - t0) * 1e3)
    return {"mean_ms": statistics.fmean(xs),
            "median_ms": statistics.median(xs),
            "p95_ms": sorted(xs)[int(0.95 * (len(xs) - 1))],
            "n": n}


def make_testbed(fast_service):
    from repro.core import Orchestrator
    from repro.substrates import standard_testbed

    orch = Orchestrator()
    adapters = standard_testbed(orch, http_service=fast_service)
    return orch, adapters
