"""§Roofline: per (arch × shape × mesh) table from the dry-run cache."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import csv_row, save

DRYRUN = Path(__file__).resolve().parent / "results" / "dryrun"


def load_cells():
    cells = []
    for f in sorted(DRYRUN.glob("*.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def run(_fast_service=None) -> list:
    cells = load_cells()
    rows = []
    ok = [c for c in cells if c.get("status") == "ok"]
    skipped = [c for c in cells if c.get("status") == "skipped"]
    failed = [c for c in cells if c.get("status") == "failed"]
    fits = [c for c in ok if c["memory"]["fits"]]
    table = []
    for c in ok:
        r = c["roofline"]
        table.append({
            "cell": c["cell"], "arch": c["arch"], "shape": c["shape"],
            "mesh": c["mesh"], "recipe": c["recipe"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "roofline_fraction": r["roofline_fraction"],
            "useful_ratio": c["model_flops"]["useful_ratio"],
            "peak_live_gb": c["memory"]["peak_live_bytes"] / 1e9,
            "fits": c["memory"]["fits"],
        })
        rows.append(csv_row(
            f"roofline/{c['cell']}", r["step_time_lb_s"] * 1e6,
            f"dom={r['dominant']} frac={r['roofline_fraction']:.3f} "
            f"fits={c['memory']['fits']}"))
    save("bench_roofline", {
        "cells_ok": len(ok), "cells_skipped": len(skipped),
        "cells_failed": len(failed), "cells_fitting": len(fits),
        "table": table,
    })
    rows.insert(0, csv_row("roofline/summary", 0.0,
                           f"{len(ok)} ok / {len(skipped)} skip / "
                           f"{len(failed)} fail / {len(fits)} fit"))
    return rows
