"""Cortical Labs path: 3 directed screening runs end-to-end (paper §VIII-A/C:
success without fallback; session handling dominates the short observation)."""
from __future__ import annotations

from repro.core import TaskRequest
from benchmarks.common import csv_row, make_testbed, save


def run(fast_service) -> list:
    orch, _ = make_testbed(fast_service)
    runs = []
    for i in range(3):
        snap_before = orch.bus.snapshot("cortical-labs-backend").to_dict()
        res, trace = orch.submit(TaskRequest(
            function="screening", input_modality="spikes",
            output_modality="spikes",
            backend_preference="cortical-labs-backend",
            payload={"pattern": [1, 0, 1, 1], "amplitude": 1.0},
            required_telemetry=("culture_health", "firing_rate_hz")))
        assert res.status == "completed" and not trace.fallback_used
        runs.append({
            "run": i,
            "health_before": snap_before["drift_score"],
            "health_after": res.telemetry["culture_health"],
            "backend_ms": res.timing_ms["backend_ms"],
            "observation_ms": res.telemetry["observation_ms"],
            "reported_session_s": res.telemetry["reported_session_s"],
            "recording": res.artifacts["recording"],
        })
    save("bench_cortical", runs)
    mean_backend = sum(r["backend_ms"] for r in runs) / 3
    mean_obs = sum(r["observation_ms"] for r in runs) / 3
    return [csv_row("cortical/backend", mean_backend * 1e3,
                    f"3/3 completed, no fallback"),
            csv_row("cortical/observation", mean_obs * 1e3,
                    f"session>>observation structure holds")]
