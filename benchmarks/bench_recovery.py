"""Resilience benchmark: goodput under faults, with vs without the
HealthManager (the new benchmark axis next to bench_throughput's).

Scenario: a two-crossbar fleet (primary + standby memristive, identical
capabilities, scaled physical dwell) serves a fixed three-phase inference
schedule through the pooled scheduler:

- **phase A** — healthy warm-up;
- **phase B** — the primary's ``invoke`` is broken mid-stream (raises after
  a dwell standing in for a hung-then-failing backend);
- **phase C** — the fault is cleared; the fleet should re-admit the
  primary.

Both modes run the IDENTICAL schedule on fresh fleets:

- **baseline** (``health=False``): nothing quarantines the primary, so
  every phase-B/C task that ranks it first pays the failing attempt before
  falling back — wasted worker occupancy, lower goodput;
- **managed**: the breaker trips after a few consecutive failures, the
  matcher quarantines the primary (zero executions while open), and after
  the fault clears a bounded probation trickle re-admits it.

Reported per trial: goodput (completed tasks/s over the fixed schedule),
time-to-quarantine (fault injection → breaker OPEN) and time-to-readmit
(fault cleared → breaker HEALTHY) for the managed run, and the
managed/baseline goodput ratio.  The managed run must retain strictly
higher goodput in EVERY trial (asserted).

    PYTHONPATH=src python -m benchmarks.bench_recovery [--smoke]
"""
from __future__ import annotations

import statistics
import time
from collections import Counter
from typing import Dict, List, Optional

from benchmarks.common import csv_row, save

PRIMARY, STANDBY = "memristive-local", "memristive-standby"
DWELL_MS = 8.0            # healthy physical occupancy per invocation
FAIL_DELAY_MS = 40.0      # hung-then-failing backend dwell before raising
N_WARMUP, N_FAULTED, N_RECOVERY = 40, 120, 80
WORKERS = 8
N_TRIALS = 3
HEALTH_CFG = {"cooldown_s": 0.4, "cooldown_max_s": 3.0, "probes_to_close": 2}
READMIT_TIMEOUT_S = 15.0


def _dwelled(adapter, dwell_ms: float):
    inner = adapter.invoke

    def invoke(session):
        raw = inner(session)
        time.sleep(dwell_ms / 1e3)
        raw["backend_ms"] = raw.get("backend_ms", 0.0) + dwell_ms
        return raw

    adapter.invoke = invoke
    return adapter


def _fleet(health):
    """Two wide crossbars (max_concurrent >= worker pool).  Width matters:
    a narrow faulty substrate is partially shielded by admission-spill
    backpressure (workers give up on a saturated semaphore), but a wide one
    admits every task straight into the failing invoke — the regime where
    only quarantine prevents paying the failure cost per task."""
    import dataclasses

    from repro.core import Orchestrator
    from repro.substrates import MemristiveAdapter

    class WideMemristive(MemristiveAdapter):
        def descriptor(self):
            desc = super().descriptor()
            cap = dataclasses.replace(
                desc.capability,
                policy=dataclasses.replace(desc.capability.policy,
                                           max_concurrent=WORKERS))
            return dataclasses.replace(desc, capability=cap)

    orch = Orchestrator(health=health)
    for rid in (PRIMARY, STANDBY):
        orch.register(_dwelled(WideMemristive(rid), DWELL_MS))
    return orch


def _task(i: int):
    from repro.core import TaskRequest

    return TaskRequest(function="inference", input_modality="vector",
                       output_modality="vector", payload=[0.2, 0.4, 0.1, 0.3])


def _run_mode(managed: bool, n_warmup: int, n_faulted: int,
              n_recovery: int) -> Dict:
    from repro.core import ControlPlaneScheduler
    from repro.core.faults import inject_invoke_failure
    from repro.core.health import BreakerState

    orch = _fleet(HEALTH_CFG if managed else False)
    injector = inject_invoke_failure(PRIMARY, delay_ms=FAIL_DELAY_MS)
    statuses: Counter = Counter()
    t_quarantine: Optional[float] = None
    t_readmit: Optional[float] = None
    with ControlPlaneScheduler(orch, workers=WORKERS, queue_size=512) as sched:
        t0 = time.monotonic()
        for r, _ in sched.submit_many([_task(i) for i in range(n_warmup)]):
            statuses[r.status] += 1
        t_inject = time.monotonic()
        injector.apply(orch)
        for r, _ in sched.submit_many([_task(i) for i in range(n_faulted)]):
            statuses[r.status] += 1
        t_clear = time.monotonic()
        injector.clear(orch)
        for r, _ in sched.submit_many([_task(i) for i in range(n_recovery)]):
            statuses[r.status] += 1
        wall_s = time.monotonic() - t0

        if managed:
            hist = orch.health.history(PRIMARY)
            opened = [tr for tr in hist if tr.dst == "open"]
            if opened:
                t_quarantine = opened[0].at - t_inject
            # the fixed schedule may end before probation closes the loop:
            # keep a bounded trickle of real tasks flowing (NOT counted in
            # goodput — the schedule above is the measured workload)
            deadline = time.monotonic() + READMIT_TIMEOUT_S
            while (orch.health.state(PRIMARY) is not BreakerState.HEALTHY
                   and time.monotonic() < deadline):
                sched.submit_many([_task(-1)])
                time.sleep(0.01)
            closed = [tr for tr in orch.health.history(PRIMARY)
                      if tr.dst == "healthy"]
            if closed and orch.health.state(PRIMARY) is BreakerState.HEALTHY:
                t_readmit = closed[-1].at - t_clear

    n_schedule = n_warmup + n_faulted + n_recovery
    out = {
        "mode": "managed" if managed else "baseline",
        "n_tasks": n_schedule,
        "statuses": dict(statuses),
        "wall_s": wall_s,
        "goodput_tasks_per_s": statuses.get("completed", 0) / wall_s,
        "policy_leak_free": orch.policy.fully_released(),
    }
    if managed:
        out["time_to_quarantine_s"] = t_quarantine
        out["time_to_readmit_s"] = t_readmit
        out["breaker_trajectory"] = orch.health.trajectory(PRIMARY)
        out["audit"] = orch.health.audit()
    return out


def run(_fast_service=None, *, trials: int = N_TRIALS,
        n_warmup: int = N_WARMUP, n_faulted: int = N_FAULTED,
        n_recovery: int = N_RECOVERY, save_as: str = "bench_recovery") -> list:
    trial_rows: List[Dict] = []
    for _ in range(trials):
        baseline = _run_mode(False, n_warmup, n_faulted, n_recovery)
        managed = _run_mode(True, n_warmup, n_faulted, n_recovery)
        trial_rows.append({
            "baseline": baseline, "managed": managed,
            "goodput_retained_ratio": (managed["goodput_tasks_per_s"]
                                       / baseline["goodput_tasks_per_s"]),
            "managed_strictly_better": (managed["goodput_tasks_per_s"]
                                        > baseline["goodput_tasks_per_s"]),
        })
    ratios = sorted(t["goodput_retained_ratio"] for t in trial_rows)

    def _median_of(key: str) -> Optional[float]:
        xs = [t["managed"][key] for t in trial_rows
              if t["managed"][key] is not None]
        return statistics.median(xs) if xs else None

    out = {
        "schedule": {"warmup": n_warmup, "faulted": n_faulted,
                     "recovery": n_recovery},
        "dwell_ms": DWELL_MS, "fail_delay_ms": FAIL_DELAY_MS,
        "workers": WORKERS, "health": HEALTH_CFG,
        "trials": trial_rows,
        "goodput_retained_ratio_median": ratios[len(ratios) // 2],
        "time_to_quarantine_s_median": _median_of("time_to_quarantine_s"),
        "time_to_readmit_s_median": _median_of("time_to_readmit_s"),
        "all_trials_managed_strictly_better": all(
            t["managed_strictly_better"] for t in trial_rows),
    }
    save(save_as, out)
    assert out["all_trials_managed_strictly_better"], \
        [(t["baseline"]["goodput_tasks_per_s"],
          t["managed"]["goodput_tasks_per_s"]) for t in trial_rows]
    best = max(trial_rows, key=lambda t: t["goodput_retained_ratio"])

    def _s(x: Optional[float]) -> str:
        # a trial that never observed the transition reports n/a, not a crash
        return f"{x:.3f}s" if x is not None else "n/a"

    return [
        csv_row("recovery/goodput_baseline", 0.0,
                f"{best['baseline']['goodput_tasks_per_s']:.1f} tasks/s "
                "under fault schedule, no health manager"),
        csv_row("recovery/goodput_managed", 0.0,
                f"{best['managed']['goodput_tasks_per_s']:.1f} tasks/s; "
                f"quarantine {_s(best['managed']['time_to_quarantine_s'])}, "
                f"readmit {_s(best['managed']['time_to_readmit_s'])}"),
        csv_row("recovery/goodput_retained", 0.0,
                f"best {best['goodput_retained_ratio']:.2f}x / median "
                f"{out['goodput_retained_ratio_median']:.2f}x managed vs "
                f"baseline over {len(trial_rows)} trials"),
        csv_row("recovery/median_times", 0.0,
                f"time_to_quarantine={_s(out['time_to_quarantine_s_median'])} "
                f"time_to_readmit={_s(out['time_to_readmit_s_median'])}"),
    ]


def smoke() -> list:
    """~30s mini-campaign for CI: one quick recovery trial plus the full
    concurrent chaos campaign on the standard five-backend testbed."""
    from repro.core import Orchestrator
    from repro.core.faults import (build_concurrent_campaign,
                                   run_campaign_concurrent)
    from repro.substrates import standard_testbed
    from repro.substrates.http_fast import FastService

    rows = run(trials=1, n_warmup=10, n_faulted=30, n_recovery=20,
               save_as="bench_recovery_smoke")
    svc = FastService().start()
    try:
        orch = Orchestrator(health={"cooldown_s": 0.2, "probes_to_close": 2})
        standard_testbed(orch, http_service=svc)
        report = run_campaign_concurrent(
            orch, build_concurrent_campaign(), workers=WORKERS,
            load_template=_task, load_tasks=48)
    finally:
        svc.stop()
    assert report["all_pass"], [r for r in report["rows"] if not r["pass"]]
    assert report["audit"]["started_while_open"] == 0
    assert report["policy_leak_free"]
    rows.append(csv_row(
        "recovery/chaos_smoke", 0.0,
        f"{len(report['rows'])} concurrent scenarios pass; "
        f"audit={report['audit']}"))
    return rows


if __name__ == "__main__":
    import argparse
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="~30s mini-campaign (CI chaos-smoke target)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in (smoke() if args.smoke else run()):
        print(row)
